//! Deadline/SLA differential corpus: AGORA's simulated annealing vs the
//! CEDCES-style evolutionary baseline on hand-checkable market problems,
//! plus the bit-identity contract of [`Goal::DeadlineCost`].
//!
//! The problems are built so the global optimum is computable by hand: a
//! one-node cluster (16 vCPUs / 64 GiB) admits exactly four catalog rows
//! (m5.4xlarge and c5.4xlarge, on-demand and spot; r5.4xlarge needs
//! 128 GiB and is excluded), zero-noise/zero-contention profiles make
//! per-task cost separable across four strictly distinct levels, and the
//! cheapest row is c5.4xlarge:spot at $0.272/h over a 1.18 speed factor.
//! Both searches must land on that optimum, which pins:
//!
//!   * SA cost is never worse than the GA at an equal evaluation budget
//!     (and both schedules pass Eq. 4 `validate`),
//!   * a binding hard deadline forces both searches onto the fast c5
//!     family, still at the spot price,
//!   * `Goal::DeadlineCost` with only unbounded SLAs is bit-identical
//!     to `Goal::Cost` — same seed, same walk, same schedule.

use agora::baselines::{EvolutionaryScheduler, Scheduler};
use agora::cluster::{catalog, Capacity, Config, ConfigSpace, CostModel};
use agora::dag::{Dag, Task, TaskProfile};
use agora::predictor::OraclePredictor;
use agora::solver::{Agora, AgoraOptions, AnnealParams, Goal, Mode, Sla};
use agora::Predictor;

/// Deterministic profile: zero noise, zero contention, tiny working set —
/// runtime at 1 node of a 16-vCPU row is exactly `work / speed_factor`.
fn exact_profile(work: f64) -> TaskProfile {
    TaskProfile {
        work,
        alpha: 0.0,
        beta: 0.0,
        mem_gb: 4.0,
        spark_affinity: 0.0,
        noise_sigma: 0.0,
    }
}

fn exact_task(name: &str, work: f64) -> Task {
    Task {
        name: name.to_string(),
        profile: exact_profile(work),
    }
}

/// Market problem with raw spot prices (no interruption surcharge).
fn market_problem(dags: &[Dag], capacity: Capacity) -> agora::solver::Problem {
    let space = ConfigSpace::market();
    let profiles: Vec<_> = dags
        .iter()
        .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
        .collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let releases = vec![0.0; dags.len()];
    agora::solver::Problem::new(
        dags,
        &releases,
        capacity,
        space,
        grid,
        CostModel::Market { interrupt_rate: 0.0 },
    )
}

/// One node's worth of capacity: the four 4xlarge m5/c5 rows, one node
/// each, are the entire feasible set.
fn one_node() -> Capacity {
    Capacity::new(16.0, 64.0)
}

/// Index of a named catalog row x nodes x balanced preset in a space.
fn market_config(space: &ConfigSpace, name: &str, nodes: u32) -> usize {
    let instance = catalog::index_by_name(name).expect("catalog row");
    space
        .configs
        .iter()
        .position(|c| {
            *c == Config {
                instance,
                nodes,
                spark: 1,
            }
        })
        .expect("market space carries every catalog row on the full ladder")
}

/// SA co-optimizer under [`Goal::DeadlineCost`] with a generous budget.
fn sa_plan(p: &agora::solver::Problem, evals: usize) -> agora::solver::Plan {
    Agora::new(AgoraOptions {
        goal: Goal::DeadlineCost,
        mode: Mode::CoOptimize,
        params: AnnealParams {
            max_iters: evals,
            patience: evals,
            ..AnnealParams::fast()
        },
        seed: 0xD1FF,
        ..Default::default()
    })
    .optimize(p)
}

// ---------------------------------------------------------------------------
// 1. Equal evaluation budget: SA cost never worse than the CEDCES-style
//    GA, and the GA itself sits exactly on the hand-computed global
//    minimum (all tasks on c5.4xlarge:spot).

#[test]
fn sa_matches_evolutionary_baseline_at_equal_eval_budget() {
    let dag = Dag::new(
        "budget",
        vec![exact_task("a", 50.0), exact_task("b", 30.0)],
        vec![],
    )
    .unwrap();
    let dags = vec![dag];
    // Loose bounded soft SLA: the deadline-cost machinery is armed but
    // the penalty term is zero at every reachable makespan, so fitness
    // and energy both reduce to pure dollar cost.
    let p = market_problem(&dags, one_node()).with_slas(vec![Sla::soft(1e6, 0.01)]);

    let evals = 800;
    let sa = sa_plan(&p, evals);
    sa.schedule.validate(&p).expect("SA schedule Eq. 4 feasible");

    let ga = EvolutionaryScheduler::with_budget(evals);
    assert_eq!(ga.evals(), evals, "budget sizing drifted");
    let ga_s = ga.schedule(&p).expect("GA schedule");
    ga_s.validate(&p).expect("GA schedule Eq. 4 feasible");
    let ga_cost = ga_s.cost(&p);

    // Hand pin: cost is separable and c5.4xlarge:spot is the strict
    // per-task minimum ($0.272/h over speed 1.18; the alternatives are
    // $0.2688/1.0, $0.680/1.18, $0.768/1.0 per unit work-hour).
    let want = 0.272 * ((50.0 + 30.0) / 1.18) / 3600.0;
    assert!(
        (ga_cost - want).abs() < 1e-9,
        "GA missed the global cost minimum: {ga_cost} vs {want}"
    );
    let c5_spot_1 = market_config(&p.space, "c5.4xlarge:spot", 1);
    for &c in &ga_s.assignment {
        assert_eq!(
            p.space.configs[c].instance, p.space.configs[c5_spot_1].instance,
            "GA assignment off the cheapest row"
        );
    }

    // The headline differential: at the same evaluation budget the
    // annealer is never worse than the evolutionary baseline.
    assert!(
        sa.cost <= ga_cost + 1e-9,
        "SA cost {} worse than GA cost {} at {} evaluations",
        sa.cost,
        ga_cost,
        evals
    );
}

// ---------------------------------------------------------------------------
// 2. A binding hard deadline: the all-m5 plans miss it, so both searches
//    must buy the fast c5 family — and still take the spot discount.

#[test]
fn hard_deadline_forces_the_fast_family_for_both_searches() {
    let dag = Dag::new(
        "deadline-chain",
        vec![exact_task("a", 60.0), exact_task("b", 60.0)],
        vec![(0, 1)],
    )
    .unwrap();
    let dags = vec![dag];
    // Chain makespans by family mix: m5+m5 = 120, m5+c5 = 60 + 60/1.18
    // ~ 110.85, c5+c5 = 120/1.18 ~ 101.69. Deadline 115 rules out the
    // all-m5 plan but leaves a single-task repair path feasible, so the
    // SA walk can cross the feasibility boundary one move at a time.
    let deadline = 115.0;
    let p = market_problem(&dags, one_node()).with_slas(vec![Sla::hard(deadline)]);

    let sa = sa_plan(&p, 600);
    sa.schedule.validate(&p).expect("SA schedule Eq. 4 feasible");

    let ga = EvolutionaryScheduler::with_budget(600);
    let ga_s = ga.schedule(&p).expect("GA schedule");
    ga_s.validate(&p).expect("GA schedule Eq. 4 feasible");

    // Cheapest deadline-feasible plan: both tasks on c5.4xlarge:spot
    // (the only cheaper row, m5.4xlarge:spot, is slower and any m5 task
    // keeps the chain above the one-m5 makespan).
    let want_cost = 0.272 * (120.0 / 1.18) / 3600.0;
    let want_makespan = 120.0 / 1.18;

    for (label, makespan, cost) in [
        ("sa", sa.makespan, sa.cost),
        ("ga", ga_s.makespan(&p), ga_s.cost(&p)),
    ] {
        assert!(
            makespan <= deadline + 1e-9,
            "{label} missed the hard deadline: {makespan} > {deadline}"
        );
        assert!(
            (makespan - want_makespan).abs() < 1e-9,
            "{label} makespan {makespan} vs {want_makespan}"
        );
        assert!(
            (cost - want_cost).abs() < 1e-9,
            "{label} cost {cost} vs {want_cost}"
        );
    }
    assert!(sa.cost <= ga_s.cost(&p) + 1e-9);
}

// ---------------------------------------------------------------------------
// 3. Bit-identity: DeadlineCost with only unbounded SLAs is Goal::Cost.

#[test]
fn deadline_cost_with_unbounded_slas_is_bit_identical_to_cost() {
    let dag = Dag::new(
        "identity",
        vec![
            exact_task("a", 40.0),
            exact_task("b", 25.0),
            exact_task("c", 10.0),
        ],
        vec![(0, 2)],
    )
    .unwrap();
    let dags = vec![dag];
    // No with_slas call: Problem::new defaults every DAG to Sla::none(),
    // which the objective's SLA fold skips entirely.
    let p = market_problem(&dags, one_node());
    assert!(p.slas.iter().all(|s| s.is_unbounded()));

    let optimize = |goal| {
        Agora::new(AgoraOptions {
            goal,
            mode: Mode::CoOptimize,
            params: AnnealParams {
                max_iters: 300,
                ..AnnealParams::fast()
            },
            seed: 0xB17,
            ..Default::default()
        })
        .optimize(&p)
    };
    let dc = optimize(Goal::DeadlineCost);
    let cost = optimize(Goal::Cost);

    // Same seed, same energy arithmetic, same walk: the plans agree to
    // the last bit.
    assert_eq!(dc.makespan.to_bits(), cost.makespan.to_bits());
    assert_eq!(dc.cost.to_bits(), cost.cost.to_bits());
    assert_eq!(dc.schedule.assignment, cost.schedule.assignment);
    assert_eq!(dc.schedule.start, cost.schedule.start);
}
