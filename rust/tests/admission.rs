//! Golden scenario for continuous multi-tenant admission: a hand-built
//! two-job trace with zero-noise profiles whose timelines are exactly
//! computable in both admission modes, pinning that
//!
//!   * round-barrier admission reproduces the historical head-of-line
//!     blocking (round 2 waits for round 1 to drain entirely);
//!   * continuous admission packs round 2 into the tail gap of round 1
//!     and reports strictly lower mean/p95 DAG completion at exactly
//!     equal cost (same configs, same realized runtimes);
//!   * arrivals landing mid-round never start before their submission;
//!   * cluster utilization improves because the horizon shrinks.
//!
//! The cluster fits exactly two default-config (8 x m5.4xlarge) tasks
//! side by side. Job "wide" (7 independent 600 s tasks) is admitted at
//! t=0 by the demand trigger and executes pairwise:
//! [0,600) x2, [600,1200) x2, [1200,1800) x2, [1800,2400) x1 — the last
//! slot leaves half the cluster idle. Job "late" (one 200 s task)
//! arrives at t=100 mid-round and is admitted by the 900 s interval
//! trigger: round-barrier mode holds it until the cluster drains at
//! t=2400 (finish 2600); continuous mode packs it into the tail gap at
//! t=1800 (finish 2000).

use agora::cluster::{Capacity, ConfigSpace};
use agora::coordinator::{Admission, BatchRunner, DagOutcome, MacroReport, Strategy};
use agora::dag::{Dag, Task, TaskProfile};
use agora::trace::TracedJob;

/// Zero-noise, zero-contention profile: realized runtime at the default
/// 8 x m5.4xlarge configuration is exactly `work / 8`.
fn exact_task(name: &str, work: f64) -> Task {
    Task {
        name: name.to_string(),
        profile: TaskProfile {
            work,
            alpha: 0.0,
            beta: 0.0,
            mem_gb: 4.0,
            spark_affinity: 0.0,
            noise_sigma: 0.0,
        },
    }
}

/// Two default-config tasks (128 vCPUs / 512 GiB each) fit side by side.
fn two_default_wide() -> Capacity {
    Capacity::new(288.0, 1152.0)
}

fn tail_gap_trace() -> Vec<TracedJob> {
    let wide = Dag::new(
        "wide",
        (0..7).map(|i| exact_task(&format!("w{i}"), 4800.0)).collect(),
        vec![],
    )
    .unwrap();
    let late = Dag::new("late", vec![exact_task("l0", 1600.0)], vec![]).unwrap();
    vec![
        TracedJob {
            dag: wide,
            submit_time: 0.0,
        },
        TracedJob {
            dag: late,
            submit_time: 100.0,
        },
    ]
}

fn run(admission: Admission) -> MacroReport {
    let jobs = tail_gap_trace();
    let mut runner = BatchRunner::new(
        two_default_wide(),
        ConfigSpace::standard(),
        Strategy::Airflow,
        42,
    )
    .with_admission(admission);
    runner.run(&jobs).expect("macro run")
}

fn outcome<'a>(rep: &'a MacroReport, name: &str) -> &'a DagOutcome {
    rep.outcomes
        .iter()
        .find(|o| o.name == name)
        .expect("outcome present")
}

#[test]
fn round_barrier_serializes_rounds_exactly() {
    let rep = run(Admission::Rounds);
    assert_eq!(rep.admission, "rounds");
    assert_eq!(rep.rounds, 2, "demand trigger + interval trigger");
    let wide = outcome(&rep, "wide");
    let late = outcome(&rep, "late");
    // Round 1: 7 x 600 s tasks, two wide -> finish 2400.
    assert!((wide.finish_time - 2400.0).abs() < 1e-6, "wide {}", wide.finish_time);
    // Round 2 waits for the full drain: 2400 + 200 = 2600.
    assert!((late.finish_time - 2600.0).abs() < 1e-6, "late {}", late.finish_time);
    assert!((late.completion - 2500.0).abs() < 1e-6);
    assert!((late.first_start - 2400.0).abs() < 1e-6);
}

#[test]
fn continuous_admission_fills_the_tail_gap() {
    let rep = run(Admission::Continuous);
    assert_eq!(rep.admission, "continuous");
    assert_eq!(rep.rounds, 2);
    let wide = outcome(&rep, "wide");
    let late = outcome(&rep, "late");
    // Round 1 is identical (empty ledger at admission).
    assert!((wide.finish_time - 2400.0).abs() < 1e-6, "wide {}", wide.finish_time);
    // Round 2 is admitted at the 900 s interval tick and packed into the
    // half-idle tail slot [1800, 2400): launch 1800, finish 2000.
    assert!((late.first_start - 1800.0).abs() < 1e-6, "late start {}", late.first_start);
    assert!((late.finish_time - 2000.0).abs() < 1e-6, "late {}", late.finish_time);
    assert!((late.completion - 1900.0).abs() < 1e-6);
    // Mid-round arrival: no task starts before its DAG's submit time,
    // nor before its round's admission instant.
    assert!(late.first_start + 1e-9 >= late.submit_time);
    assert!(late.first_start + 1e-9 >= 900.0);
}

#[test]
fn continuous_strictly_beats_round_barrier_at_equal_cost() {
    let rounds = run(Admission::Rounds);
    let continuous = run(Admission::Continuous);

    // Equal cost budget: same strategy, seed and configs draw the same
    // realized runtimes, so the dollar columns are identical.
    assert!(
        (rounds.total_cost - continuous.total_cost).abs() < 1e-9,
        "cost drifted: {} vs {}",
        rounds.total_cost,
        continuous.total_cost
    );

    // The §5.5 headline for continuous admission: strictly lower mean
    // and p95 DAG completion, strictly higher utilization (same busy
    // core-seconds over a shorter horizon), strictly lower queueing
    // delay.
    assert!(
        continuous.mean_completion < rounds.mean_completion - 1.0,
        "mean completion must strictly improve: {} vs {}",
        continuous.mean_completion,
        rounds.mean_completion
    );
    assert!(
        continuous.p95_completion < rounds.p95_completion - 1.0,
        "p95 completion must strictly improve: {} vs {}",
        continuous.p95_completion,
        rounds.p95_completion
    );
    assert!(
        continuous.utilization > rounds.utilization + 1e-6,
        "utilization must improve: {} vs {}",
        continuous.utilization,
        rounds.utilization
    );
    assert!(continuous.mean_queue_delay < rounds.mean_queue_delay - 1.0);

    // Exact means from the hand timeline: (2400 + 2500)/2 vs
    // (2400 + 1900)/2.
    assert!((rounds.mean_completion - 2450.0).abs() < 1e-6);
    assert!((continuous.mean_completion - 2150.0).abs() < 1e-6);
}

#[test]
fn continuous_mode_never_exceeds_capacity_across_rounds() {
    // Cross-round capacity feasibility: replay the per-DAG first-start /
    // finish windows; at no instant may the aggregate demand of the two
    // rounds exceed the cluster. (Coarse check at outcome granularity —
    // the fine-grained check lives in the executor invariants; here we
    // pin that the "late" task was not overlapped onto a full cluster.)
    let rep = run(Admission::Continuous);
    let late = outcome(&rep, "late");
    // During [1200, 1800) the cluster is full (two wide tasks): the late
    // task must not have been launched there.
    assert!(late.first_start + 1e-9 >= 1800.0);
}
