//! Control-plane integration tests: the actor-style service against a
//! verbatim reimplementation of the pre-refactor serial loop (the
//! bit-for-bit pin), plus backpressure, fairness, priority, retry and
//! shutdown-drain behaviour.

use std::collections::HashMap;
use std::time::Duration;

use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::coordinator::service::{Service, ServiceConfig};
use agora::coordinator::{
    Admission, FaultSpec, Priority, RetryPolicy, SlaPolicy, SubmitError, TriggerPolicy,
};
use agora::dag::workloads::{dag1, dag2, fig1_dag};
use agora::predictor::{
    bootstrap_history, profiling_configs_for, scoped_task_name, EventLog,
};
use agora::sim::{execute_with_policy, ReplanPolicy};
use agora::solver::{
    Agora, AgoraOptions, AnnealParams, Goal, Mode, Problem, Reservation,
};
use agora::util::Rng;
use agora::{Dag, LearnedPredictor, Predictor};

/// The pre-refactor `Service` round loop, inlined on public APIs: one
/// RNG stream consumed serially as `bootstrap(N) -> seed(N) ->
/// execute(N) -> bootstrap(N+1) -> ...`, with the continuous-admission
/// occupancy ledger reimplemented verbatim.
struct LegacyLoop {
    capacity: Capacity,
    space: ConfigSpace,
    cost_model: CostModel,
    replan: ReplanPolicy,
    goal: Goal,
    parallelism: usize,
    admission: Admission,
    rng: Rng,
    log_db: HashMap<String, EventLog>,
    reservations: Vec<Reservation>,
}

impl LegacyLoop {
    fn new(seed: u64, admission: Admission) -> LegacyLoop {
        LegacyLoop {
            capacity: Capacity::micro(),
            space: ConfigSpace::standard(),
            cost_model: CostModel::OnDemand,
            replan: ReplanPolicy::off(),
            goal: Goal::Balanced,
            parallelism: 1,
            admission,
            rng: Rng::new(seed),
            log_db: HashMap::new(),
            reservations: Vec::new(),
        }
    }

    /// Serve one round over `dags`; returns (completion, cost) per DAG.
    fn round(&mut self, round: usize, dags: &[Dag]) -> Vec<(f64, f64)> {
        let releases = vec![0.0f64; dags.len()];
        let profiling = profiling_configs_for(&self.space);
        let mut logs: Vec<EventLog> = Vec::new();
        for d in dags {
            for t in &d.tasks {
                let key = scoped_task_name(&d.name, &t.name);
                let entry = self.log_db.entry(key.clone()).or_insert_with(|| {
                    bootstrap_history(&key, &t.profile, &profiling, &mut self.rng)
                });
                logs.push(entry.clone());
            }
        }
        let grid = LearnedPredictor::fit(&logs).predict(&self.space);
        let mut p = Problem::new(
            dags,
            &releases,
            self.capacity,
            self.space.clone(),
            grid,
            self.cost_model.clone(),
        );
        let vnow = match self.admission {
            Admission::Rounds => 0.0,
            Admission::Continuous => {
                (round as f64 - 1.0) * TriggerPolicy::default().interval
            }
        };
        if self.admission == Admission::Continuous {
            self.reservations.retain(|&(s, d, _, _)| s + d > vnow);
            let mut shifted: Vec<Reservation> = self
                .reservations
                .iter()
                .map(|&(s, d, cpu, mem)| (s - vnow, d, cpu, mem))
                .collect();
            shifted.sort_by(|a, b| a.0.total_cmp(&b.0));
            p = p.with_occupancy(shifted, 0.0);
        }
        let seed = self.rng.next_u64();
        let plan = Agora::new(AgoraOptions {
            goal: self.goal,
            mode: Mode::CoOptimize,
            params: AnnealParams::fast(),
            seed,
            parallelism: self.parallelism,
            ..Default::default()
        })
        .optimize(&p);
        let report = execute_with_policy(
            &p,
            dags,
            &plan.schedule,
            &self.cost_model,
            &mut self.rng,
            &self.replan.for_round(round as u64 - 1),
        );
        if self.admission == Admission::Continuous {
            for r in &report.records {
                let cfg = p.space.configs[r.config];
                self.reservations
                    .push((vnow + r.start, r.runtime, cfg.vcpus(), cfg.memory_gb()));
            }
        }
        for (t, log) in report.new_logs.iter().enumerate() {
            let key = p.tasks[t].name.clone();
            let entry = self
                .log_db
                .entry(key)
                .or_insert_with(|| EventLog::new(&p.tasks[t].name));
            entry.runs.extend(log.runs.iter().cloned());
        }
        (0..dags.len())
            .map(|d| {
                let cost: f64 = report
                    .records
                    .iter()
                    .filter(|r| p.tasks[r.task].dag == d)
                    .map(|r| {
                        self.cost_model
                            .realized_cost(&p.space.configs[r.config], r.runtime)
                    })
                    .sum();
                (report.dag_completion[d], cost)
            })
            .collect()
    }
}

/// Drive the real service through `batches`, one demand-triggered round
/// per batch (the window is far away; `max_queue` equals the batch
/// size), waiting for every reply before the next batch so rounds stay
/// strictly serial. Returns (round, completion bits, cost bits) in
/// submission order.
fn drive_service(seed: u64, admission: Admission, batches: &[Vec<Dag>]) -> Vec<(usize, u64, u64)> {
    let per_batch = batches[0].len();
    assert!(batches.iter().all(|b| b.len() == per_batch));
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_secs(60),
        max_queue: per_batch,
        seed,
        admission,
        ..Default::default()
    });
    let handle = service.handle();
    let mut got = Vec::new();
    for (b, dags) in batches.iter().enumerate() {
        let tickets: Vec<_> = dags
            .iter()
            .enumerate()
            .map(|(i, d)| {
                handle
                    .submit(&format!("tenant{b}x{i}"), d.clone())
                    .expect("admitted")
            })
            .collect();
        for t in tickets {
            let r = t.recv_timeout(Duration::from_secs(180)).expect("served");
            got.push((r.round, r.completion.to_bits(), r.cost.to_bits()));
        }
    }
    assert_eq!(service.shutdown().expect("clean shutdown"), batches.len());
    got
}

#[test]
fn single_worker_service_is_bit_identical_to_the_legacy_serial_loop() {
    let seed = 0x5E21; // ServiceConfig::default().seed
    let batches = vec![
        vec![dag1(), dag2()],
        vec![fig1_dag(), dag1()],
        vec![dag2(), fig1_dag()],
    ];
    let got = drive_service(seed, Admission::Rounds, &batches);

    let mut legacy = LegacyLoop::new(seed, Admission::Rounds);
    let mut want = Vec::new();
    for (b, dags) in batches.iter().enumerate() {
        for (completion, cost) in legacy.round(b + 1, dags) {
            want.push((b + 1, completion.to_bits(), cost.to_bits()));
        }
    }
    assert_eq!(got, want);
}

#[test]
fn continuous_single_worker_service_pins_the_legacy_ledger_stream() {
    let seed = 41;
    let batches = vec![vec![dag1(), dag2()], vec![dag2(), fig1_dag()]];
    let got = drive_service(seed, Admission::Continuous, &batches);

    let mut legacy = LegacyLoop::new(seed, Admission::Continuous);
    let mut want = Vec::new();
    for (b, dags) in batches.iter().enumerate() {
        for (completion, cost) in legacy.round(b + 1, dags) {
            want.push((b + 1, completion.to_bits(), cost.to_bits()));
        }
    }
    assert_eq!(got, want);
}

#[test]
fn backpressure_rejects_at_exactly_the_queue_bound() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_secs(60),
        max_queue: 100, // nothing drains until shutdown
        queue_bound: 2,
        ..Default::default()
    });
    let handle = service.handle();
    let t1 = handle.submit("a", dag1()).expect("first admitted");
    let t2 = handle.submit("a", dag1()).expect("second admitted");
    match handle.submit("a", dag1()) {
        Err(SubmitError::QueueFull { tenant, bound }) => {
            assert_eq!(tenant, "a");
            assert_eq!(bound, 2);
        }
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // The bound is per tenant; a quiet tenant is unaffected.
    let t3 = handle.submit("b", dag2()).expect("other tenant admitted");
    let status = handle.status();
    assert_eq!(status.accepted, 3);
    assert_eq!(status.rejected, 1);
    let a = status.tenants.iter().find(|t| t.tenant == "a").unwrap();
    assert_eq!((a.queued, a.rejected), (2, 1));
    // Shutdown drains: every admitted ticket is still answered.
    assert!(service.shutdown().expect("clean shutdown") >= 1);
    for t in [t1, t2, t3] {
        let r = t.recv_timeout(Duration::from_secs(120)).expect("served");
        assert!(r.completion > 0.0 && r.cost > 0.0);
    }
}

#[test]
fn capped_batches_round_robin_flooder_and_victim() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_secs(60),
        max_queue: 5,  // the fifth submission arms the demand trigger
        max_batch: 2, // ... but a round takes at most two
        ..Default::default()
    });
    let handle = service.handle();
    let floods: Vec<_> = (0..4)
        .map(|_| handle.submit("flood", dag1()).expect("admitted"))
        .collect();
    let victim = handle.submit("victim", dag2()).expect("admitted");
    // Round-robin across tenants: the victim shares round 1 with one
    // flood submission instead of queueing behind all four.
    let v = victim.recv_timeout(Duration::from_secs(120)).expect("served");
    let f0 = floods[0]
        .recv_timeout(Duration::from_secs(120))
        .expect("served");
    assert_eq!(v.round, 1);
    assert_eq!(f0.round, 1);
    // The remaining flood backlog drains in later capped rounds.
    assert_eq!(service.shutdown().expect("clean shutdown"), 3);
    for t in &floods[1..] {
        let r = t.recv_timeout(Duration::from_secs(120)).expect("served");
        assert!(r.round >= 2);
    }
}

#[test]
fn high_priority_jumps_capped_batches() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_secs(60),
        max_queue: 3,
        max_batch: 1,
        ..Default::default()
    });
    let handle = service.handle();
    let lo = handle
        .submit_with_priority("lo", dag1(), Priority::Low)
        .expect("admitted");
    let mid = handle
        .submit_with_priority("mid", dag2(), Priority::Normal)
        .expect("admitted");
    let hi = handle
        .submit_with_priority("hi", fig1_dag(), Priority::High)
        .expect("admitted");
    // Demand trigger fires at three queued; the capped round takes the
    // high-priority submission despite it arriving last.
    let r_hi = hi.recv_timeout(Duration::from_secs(120)).expect("served");
    assert_eq!(r_hi.round, 1);
    service.shutdown().expect("clean shutdown");
    let r_mid = mid.recv_timeout(Duration::from_secs(120)).expect("served");
    let r_lo = lo.recv_timeout(Duration::from_secs(120)).expect("served");
    assert_eq!(r_mid.round, 2);
    assert_eq!(r_lo.round, 3);
}

#[test]
fn graceful_shutdown_drains_every_ticket() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_secs(60),
        max_queue: 100, // neither trigger fires before shutdown
        ..Default::default()
    });
    let handle = service.handle();
    let tickets: Vec<_> = (0..5)
        .map(|i| {
            let dag = if i % 2 == 0 { dag1() } else { dag2() };
            handle.submit(&format!("t{i}"), dag).expect("admitted")
        })
        .collect();
    assert!(service.shutdown().expect("clean shutdown") >= 1);
    for t in tickets {
        let r = t.recv_timeout(Duration::from_secs(120)).expect("served");
        assert!(r.completion > 0.0 && r.cost > 0.0);
    }
}

#[test]
fn injected_fault_retries_and_recovers() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_millis(30),
        fault: FaultSpec {
            optimize_failures: 1,
        },
        retry: RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(5),
            factor: 2.0,
            cap: Duration::from_millis(50),
        },
        ..Default::default()
    });
    let handle = service.handle();
    let t = handle.submit("a", dag1()).expect("admitted");
    let r = t.recv_timeout(Duration::from_secs(120)).expect("served");
    assert!(r.completion > 0.0 && r.cost > 0.0);
    let status = handle.status();
    assert!(status.rounds_retried >= 1);
    assert_eq!(status.rounds_failed, 0);
    service.shutdown().expect("clean shutdown");
}

#[test]
fn exhausted_retries_answer_tickets_with_the_round_error() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_millis(30),
        fault: FaultSpec {
            optimize_failures: 99,
        },
        retry: RetryPolicy {
            max_attempts: 2,
            base: Duration::from_millis(5),
            factor: 2.0,
            cap: Duration::from_millis(20),
        },
        ..Default::default()
    });
    let handle = service.handle();
    let t = handle.submit("a", dag1()).expect("admitted");
    let err = t
        .recv_timeout(Duration::from_secs(60))
        .expect_err("the round must fail terminally");
    let msg = format!("{err}");
    assert!(msg.contains("2 attempt(s)"), "unexpected error: {msg}");
    assert!(msg.contains("injected optimizer fault"), "unexpected error: {msg}");
    assert!(handle.status().rounds_failed >= 1);
    // A failed round does not wedge the service: clear the fault via a
    // live reload and serve a fresh round.
    handle.reload(ServiceConfig {
        batch_window: Duration::from_millis(30),
        ..Default::default()
    });
    let t2 = handle.submit("a", dag2()).expect("admitted");
    let r2 = t2.recv_timeout(Duration::from_secs(120)).expect("served");
    assert!(r2.completion > 0.0 && r2.cost > 0.0);
    service.shutdown().expect("clean shutdown");
}

#[test]
fn reloaded_sla_policy_applies_only_to_later_dispatched_rounds() {
    // Round 1 dispatches under the default (SLA-off) config and must be
    // served normally. A live reload then arms an impossibly tight hard
    // SLA (deadline at 1% of the completion lower bound), so the next
    // dispatched round rejects its DAG with an error ticket — proving
    // the reload snapshot is taken per dispatch, not per submission.
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_millis(30),
        ..Default::default()
    });
    let handle = service.handle();

    let before = handle.submit("a", dag1()).expect("admitted");
    let r1 = before
        .recv_timeout(Duration::from_secs(120))
        .expect("served under the pre-reload, SLA-off config");
    assert!(r1.completion > 0.0 && r1.cost > 0.0);

    handle.reload(ServiceConfig {
        batch_window: Duration::from_millis(30),
        sla: SlaPolicy {
            deadline_frac: 0.01,
            penalty_per_sec: 0.0,
            hard: true,
            enforce: true,
        },
        ..Default::default()
    });
    let after = handle.submit("a", dag1()).expect("admission still accepts");
    let err = after
        .recv_timeout(Duration::from_secs(60))
        .expect_err("the post-reload round must reject the DAG");
    let msg = format!("{err}");
    assert!(msg.contains("rejected"), "unexpected error: {msg}");
    assert!(msg.contains("hard deadline"), "unexpected error: {msg}");
    assert!(handle.status().rejected >= 1);

    // Rejection does not wedge the service: disarm and serve again.
    handle.reload(ServiceConfig {
        batch_window: Duration::from_millis(30),
        ..Default::default()
    });
    let t3 = handle.submit("a", dag2()).expect("admitted");
    let r3 = t3.recv_timeout(Duration::from_secs(120)).expect("served");
    assert!(r3.completion > 0.0 && r3.cost > 0.0);
    service.shutdown().expect("clean shutdown");
}

#[test]
fn multi_worker_pool_serves_every_tenant() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_millis(20),
        max_queue: 2,
        workers: 3,
        ..Default::default()
    });
    let handle = service.handle();
    assert_eq!(handle.status().workers, 3);
    let tickets: Vec<_> = (0..6)
        .map(|i| handle.submit(&format!("t{i}"), dag1()).expect("admitted"))
        .collect();
    for t in tickets {
        let r = t.recv_timeout(Duration::from_secs(180)).expect("served");
        assert!(r.completion > 0.0 && r.cost > 0.0);
    }
    let status = handle.status();
    assert!(status.dags_served >= 6);
    assert!(service.shutdown().expect("clean shutdown") >= 1);
}
