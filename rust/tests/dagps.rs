//! DAGPS differential suite: the troublesome-subgraph baseline beats
//! critical-path list scheduling on a hand-built resource-skewed deep
//! instance (exact pins), the scoring is deterministic and stable under
//! task-index permutation, and the troublesome-first SA seeding never
//! degrades the golden-scenario objectives.

use agora::baselines::{CriticalPathScheduler, DagpsScheduler, Scheduler};
use agora::cluster::{catalog, Capacity, Config, ConfigSpace, CostModel};
use agora::dag::generator::large_scale_dag;
use agora::dag::workloads::{dag1, dag2};
use agora::predictor::{Grid, OraclePredictor};
use agora::solver::objective::Objective;
use agora::solver::sgs::{priorities, serial_sgs, troublesome_components, troublesome_scores, Rule};
use agora::solver::{anneal, portfolio_anneal, AnnealParams, Goal, Problem};
use agora::util::Rng;
use agora::{Dag, Predictor, Task, TaskProfile};

/// The differential instance: a 48-vCPU / 96-GB cluster where three thin
/// tasks pack exactly, a fat task tolerates exactly one thin neighbour,
/// and two fat tasks never coexist.
///
/// - Tasks 0..8 ("P") and 8..16 ("Q"): two chains of eight thin tasks,
///   1.25 s each on a 1×c5.4xlarge (16 vCPU, 32 GB — skew 1.0).
/// - Tasks 16..19 ("A") and 19..22 ("B"): two chains of three fat tasks,
///   3 s each on a 1×m5.4xlarge (16 vCPU, 64 GB — skew 4/3).
///
/// Critical-path order starts the thin chains and only then discovers
/// the fat chains must serialize, finishing at 19.5 s; troublesome-first
/// packing front-loads the fat pairs {A1,A2} and {B1,B2} and finishes at
/// 19.25 s. Every start/end in both schedules is an exact multiple of
/// 0.25, so the pins compare exactly in f64.
fn skewed_instance() -> (Problem, Vec<usize>) {
    let thin_dur = 1.25;
    let fat_dur = 3.0;
    let task = |n: String| Task {
        name: n,
        profile: TaskProfile::example(),
    };
    let mut tasks = Vec::new();
    let mut edges = Vec::new();
    for chain in ["P", "Q"] {
        let base = tasks.len();
        for i in 0..8 {
            tasks.push(task(format!("{chain}{}", i + 1)));
            if i > 0 {
                edges.push((base + i - 1, base + i));
            }
        }
    }
    for chain in ["A", "B"] {
        let base = tasks.len();
        for i in 0..3 {
            tasks.push(task(format!("{chain}{}", i + 1)));
            if i > 0 {
                edges.push((base + i - 1, base + i));
            }
        }
    }
    let dag = Dag::new("skewed", tasks, edges).unwrap();

    let thin = Config {
        instance: catalog::index_by_name("c5.4xlarge").unwrap(),
        nodes: 1,
        spark: 1,
    };
    let fat = Config {
        instance: catalog::index_by_name("m5.4xlarge").unwrap(),
        nodes: 1,
        spark: 1,
    };
    assert_eq!((thin.vcpus(), thin.memory_gb()), (16.0, 32.0));
    assert_eq!((fat.vcpus(), fat.memory_gb()), (16.0, 64.0));
    let space = ConfigSpace {
        configs: vec![thin, fat],
    };

    // Hand-built grid: thin rows run in 1.25 s, fat rows in 3 s,
    // regardless of config — the assignment below pins which is used.
    let durations: Vec<Vec<f64>> = (0..22)
        .map(|t| {
            let d = if t < 16 { thin_dur } else { fat_dur };
            vec![d, d]
        })
        .collect();
    let p = Problem::new(
        &[dag],
        &[0.0],
        Capacity::new(48.0, 96.0),
        space,
        Grid { durations },
        CostModel::OnDemand,
    );
    // P/Q on the thin config (index 0), A/B on the fat config (index 1).
    let assignment: Vec<usize> = (0..22).map(|t| usize::from(t >= 16)).collect();
    (p, assignment)
}

#[test]
fn dagps_beats_critical_path_on_the_skewed_instance_with_exact_pins() {
    let (p, assignment) = skewed_instance();

    let cp = CriticalPathScheduler::with_assignment(assignment.clone())
        .schedule(&p)
        .unwrap();
    cp.validate(&p).unwrap();
    let dagps = DagpsScheduler::with_assignment(assignment).schedule(&p).unwrap();
    dagps.validate(&p).unwrap();

    let (m_cp, m_dagps) = (cp.makespan(&p), dagps.makespan(&p));
    assert!(
        m_dagps < m_cp,
        "troublesome-first packing must beat critical path: {m_dagps} vs {m_cp}"
    );
    // Exact pins (every placement is a multiple of 0.25 s).
    assert!((m_cp - 19.5).abs() < 1e-9, "critical-path pin moved: {m_cp}");
    assert!((m_dagps - 19.25).abs() < 1e-9, "dagps pin moved: {m_dagps}");
}

#[test]
fn troublesome_scoring_marks_the_fat_chain_prefixes() {
    let (p, assignment) = skewed_instance();
    let scores = troublesome_scores(&p, &assignment);

    // Hand-computed: duration/3 × skew × bottom/10.
    let expect = |t: usize| match t {
        16 | 19 => 1.2,          // A1/B1: 1.0 × 4/3 × 0.9
        17 | 20 => 0.8,          // A2/B2: 1.0 × 4/3 × 0.6
        18 | 21 => 0.4,          // A3/B3: 1.0 × 4/3 × 0.3
        0 | 8 => 1.25 / 3.0,     // P1/Q1: full-depth thin heads, skew 1
        _ => f64::NAN,           // unchecked tail entries
    };
    for t in [16, 17, 18, 19, 20, 21, 0, 8] {
        assert!(
            (scores[t] - expect(t)).abs() < 1e-12,
            "score[{t}] = {}, expected {}",
            scores[t],
            expect(t)
        );
    }

    // Threshold 0.6 marks exactly the fat-chain prefixes, which grow
    // into the two precedence-connected components, A-pair ranked first.
    let comps = troublesome_components(&p, &scores);
    assert_eq!(comps, vec![vec![16, 17], vec![19, 20]]);
}

#[test]
fn troublesome_scoring_is_deterministic_and_permutation_stable() {
    let (p, assignment) = skewed_instance();
    let s1 = troublesome_scores(&p, &assignment);
    let s2 = troublesome_scores(&p, &assignment);
    assert_eq!(s1, s2, "scoring must be deterministic");
    assert_eq!(
        troublesome_components(&p, &s1),
        troublesome_components(&p, &s2)
    );

    // Rebuild the same instance with task indices reversed: scores must
    // follow the permutation exactly, and the component family must map
    // to the same sets of (renamed) tasks.
    let n = 22;
    let perm = |t: usize| n - 1 - t;
    let (orig, _) = skewed_instance();
    let tasks: Vec<Task> = (0..n)
        .map(|t| Task {
            name: format!("perm-{t}"),
            profile: TaskProfile::example(),
        })
        .collect();
    let edges: Vec<(usize, usize)> = orig
        .precedence
        .iter()
        .map(|&(a, b)| (perm(a), perm(b)))
        .collect();
    let dag = Dag::new("skewed-perm", tasks, edges).unwrap();
    let durations: Vec<Vec<f64>> = (0..n)
        .map(|t| orig.grid.durations[perm(t)].clone())
        .collect();
    let p2 = Problem::new(
        &[dag],
        &[0.0],
        Capacity::new(48.0, 96.0),
        ConfigSpace {
            configs: orig.space.configs.clone(),
        },
        Grid { durations },
        CostModel::OnDemand,
    );
    let assignment2: Vec<usize> = (0..n).map(|t| assignment[perm(t)]).collect();
    let s3 = troublesome_scores(&p2, &assignment2);
    for t in 0..n {
        assert_eq!(
            s1[t].to_bits(),
            s3[perm(t)].to_bits(),
            "score of task {t} moved under permutation"
        );
    }
    let as_sets = |comps: &[Vec<usize>], f: &dyn Fn(usize) -> usize| {
        let mut sets: Vec<Vec<usize>> = comps
            .iter()
            .map(|c| {
                let mut m: Vec<usize> = c.iter().map(|&t| f(t)).collect();
                m.sort_unstable();
                m
            })
            .collect();
        sets.sort();
        sets
    };
    let id = |t: usize| t;
    assert_eq!(
        as_sets(&troublesome_components(&p, &s1), &perm),
        as_sets(&troublesome_components(&p2, &s3), &id),
        "component family must map through the permutation"
    );
}

#[test]
fn troublesome_rule_schedules_the_skewed_instance_like_the_baseline() {
    // The baseline is a thin wrapper over Rule::Troublesome + serial
    // SGS; pin that equivalence so the two reuse points can't drift.
    let (p, assignment) = skewed_instance();
    let prio = priorities(&p, &assignment, Rule::Troublesome);
    let direct = serial_sgs(&p, &assignment, &prio).unwrap();
    let via_baseline = DagpsScheduler::with_assignment(assignment).schedule(&p).unwrap();
    assert_eq!(direct.start, via_baseline.start);
    assert_eq!(direct.assignment, via_baseline.assignment);
}

fn oracle_problem(dags: Vec<Dag>, cap: Capacity) -> Problem {
    let space = ConfigSpace::standard();
    let profiles: Vec<_> = dags
        .iter()
        .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
        .collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let releases = vec![0.0; dags.len()];
    Problem::new(&dags, &releases, cap, space, grid, CostModel::OnDemand)
}

#[test]
fn troublesome_seeding_never_degrades_golden_scenario_objectives() {
    // Structural guarantee, not a statistical one: with the exchange
    // disabled, chain 0 of the seeded portfolio replays the unseeded
    // single chain exactly (same params, same RNG stream, same start),
    // so the portfolio winner — the minimum over chains — can only match
    // or improve the unseeded objective. Checked on the two evaluation
    // DAGs and a wide-fan-out large-scale instance.
    let mut gen_rng = Rng::new(0xFA7);
    let scenarios: Vec<(&str, Problem)> = vec![
        ("dag1+dag2", oracle_problem(vec![dag1(), dag2()], Capacity::micro())),
        (
            "large-scale",
            oracle_problem(
                vec![large_scale_dag(&mut gen_rng, "wide", 120)],
                Capacity::micro(),
            ),
        ),
    ];
    for (name, p) in scenarios {
        let init = vec![p.feasible[0]; p.len()];
        let prio = priorities(&p, &init, Rule::CriticalPath);
        let s0 = serial_sgs(&p, &init, &prio).unwrap();
        let objective = Objective::new(Goal::Balanced, s0.makespan(&p), s0.cost(&p));
        let params = AnnealParams {
            max_iters: 120,
            patience: 120,
            exchange_interval: 0,
            troublesome_seed: true,
            ..AnnealParams::fast()
        };
        let seeded = portfolio_anneal(&p, &objective, &init, &params, 2, 0x5EED);
        let mut rng = Rng::new(0x5EED);
        let unseeded = anneal(&p, &objective, &init, &params, &mut rng);
        assert!(
            seeded.energy <= unseeded.energy + 1e-12,
            "{name}: seeded portfolio {} degraded the unseeded chain {}",
            seeded.energy,
            unseeded.energy
        );
        seeded.schedule.validate(&p).unwrap();
    }
}
