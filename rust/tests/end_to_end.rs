//! End-to-end system tests: micro pipelines through the full stack, the
//! threaded multi-tenant service, the macro trace runner, and the
//! adaptive feedback loop.

use std::time::Duration;

use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::coordinator::service::{Service, ServiceConfig};
use agora::coordinator::{BatchRunner, MacroSummary, Strategy};
use agora::dag::workloads::{dag1, dag2, fig1_dag};
use agora::predictor::{bootstrap_history, default_profiling_configs, EventLog, LearnedPredictor};
use agora::solver::{Agora, AgoraOptions, AnnealParams, Goal, Mode};
use agora::trace::{generate, TraceParams};
use agora::util::Rng;
use agora::Predictor;

#[test]
fn micro_pipeline_balanced_beats_airflow_on_both_axes() {
    // The Fig. 7 headline, as a regression test: balanced AGORA must
    // dominate default Airflow on DAG2 (high-parallelism DAG).
    use agora::baselines::{AirflowScheduler, Scheduler};
    let dags = vec![dag2()];
    let mut rng = Rng::new(2022);
    let logs: Vec<EventLog> = dags[0]
        .tasks
        .iter()
        .map(|t| bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), &mut rng))
        .collect();
    let p = Agora::build_problem(
        &dags,
        &[0.0],
        &logs,
        Capacity::micro(),
        ConfigSpace::standard(),
        CostModel::OnDemand,
    );
    let airflow = AirflowScheduler::default().schedule(&p).expect("airflow");
    let plan = Agora::new(AgoraOptions {
        goal: Goal::Balanced,
        seed: 2022,
        ..Default::default()
    })
    .optimize(&p);

    let mut rng_a = Rng::new(0xE0E0);
    let rep_air = agora::sim::execute(&p, &dags, &airflow, &CostModel::OnDemand, &mut rng_a);
    let mut rng_b = Rng::new(0xE0E0);
    let rep_ag = agora::sim::execute(&p, &dags, &plan.schedule, &CostModel::OnDemand, &mut rng_b);

    assert!(
        rep_ag.makespan < rep_air.makespan,
        "AGORA realized {} vs airflow {}",
        rep_ag.makespan,
        rep_air.makespan
    );
    assert!(
        rep_ag.cost < rep_air.cost,
        "AGORA cost {} vs airflow {}",
        rep_ag.cost,
        rep_air.cost
    );
}

#[test]
fn adaptive_loop_improves_predictions() {
    // §4.1: feeding executed event logs back reduces prediction error.
    let dags = vec![dag1()];
    let space = ConfigSpace::standard();
    let mut rng = Rng::new(5);
    let mut logs: Vec<EventLog> = dags[0]
        .tasks
        .iter()
        .map(|t| {
            bootstrap_history(
                &t.name,
                &t.profile,
                // thin history: a single prior run
                &default_profiling_configs()[..1],
                &mut rng,
            )
        })
        .collect();

    let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
    let err_before = agora::predictor::mape(
        &LearnedPredictor::fit(&logs).predict(&space),
        &profiles,
        &space,
    );

    // Run three optimize->execute->feedback rounds.
    for round in 0..3 {
        let p = Agora::build_problem(
            &dags,
            &[0.0],
            &logs,
            Capacity::micro(),
            space.clone(),
            CostModel::OnDemand,
        );
        let plan = Agora::new(AgoraOptions {
            goal: Goal::Balanced,
            params: AnnealParams::fast(),
            seed: round,
            ..Default::default()
        })
        .optimize(&p);
        let report = agora::sim::execute(&p, &dags, &plan.schedule, &CostModel::OnDemand, &mut rng);
        for (t, log) in report.new_logs.iter().enumerate() {
            logs[t].runs.extend(log.runs.iter().cloned());
        }
    }

    let err_after = agora::predictor::mape(
        &LearnedPredictor::fit(&logs).predict(&space),
        &profiles,
        &space,
    );
    assert!(
        err_after < err_before,
        "adaptive loop should reduce MAPE: before {err_before:.3} after {err_after:.3}"
    );
}

#[test]
fn macro_trace_agora_beats_airflow_on_cost_and_completion() {
    let params = TraceParams {
        jobs: 16,
        window: 3600.0,
        machines: 16,
        ..TraceParams::default()
    };
    let mut rng = Rng::new(11);
    let jobs = generate(&params, &mut rng);

    let base = BatchRunner::new(
        params.batch_capacity(),
        ConfigSpace::standard(),
        Strategy::Airflow,
        11,
    )
    .run(&jobs)
    .expect("airflow macro run");
    let run = BatchRunner::new(
        params.batch_capacity(),
        ConfigSpace::standard(),
        Strategy::Agora(Goal::Balanced),
        11,
    )
    .run(&jobs)
    .expect("agora macro run");

    let s = MacroSummary::against(&base, &run);
    assert!(
        s.normalized_cost < 1.0,
        "normalized cost {} should be < 1",
        s.normalized_cost
    );
    assert!(
        s.improved_fraction > 0.5,
        "most DAGs should improve: {}",
        s.improved_fraction
    );
}

#[test]
fn ablation_ordering_matches_paper_shape() {
    // Fig. 8: co-optimization should not lose to AGORA-separate on the
    // combined balanced metric for DAG2.
    let dags = vec![dag2()];
    let mut rng = Rng::new(2022);
    let logs: Vec<EventLog> = dags[0]
        .tasks
        .iter()
        .map(|t| bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), &mut rng))
        .collect();
    let p = Agora::build_problem(
        &dags,
        &[0.0],
        &logs,
        Capacity::micro(),
        ConfigSpace::standard(),
        CostModel::OnDemand,
    );
    let run = |mode: Mode| {
        let plan = Agora::new(AgoraOptions {
            goal: Goal::Balanced,
            mode,
            params: AnnealParams::fast(),
            seed: 2022,
            ..Default::default()
        })
        .optimize(&p);
        (plan.makespan, plan.cost)
    };
    let (m_co, c_co) = run(Mode::CoOptimize);
    let (m_sep, c_sep) = run(Mode::Separate);
    let combined_co = 0.5 * m_co / m_sep + 0.5 * c_co / c_sep;
    assert!(
        combined_co <= 1.05,
        "co-optimize should not lose to separate: {combined_co:.3}"
    );
}

#[test]
fn service_round_trip_under_concurrent_submissions() {
    let service = Service::start(ServiceConfig {
        batch_window: Duration::from_millis(40),
        max_queue: 3,
        seed: 9,
        ..Default::default()
    });
    let handle = service.handle();
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            let dag = match i {
                0 => dag1(),
                1 => dag2(),
                _ => fig1_dag(),
            };
            handle.submit(&format!("tenant{i}"), dag).expect("admitted")
        })
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("served");
        assert!(r.completion > 0.0);
        assert!(r.cost > 0.0);
    }
    assert!(service.shutdown().expect("clean shutdown") >= 1);
}

#[test]
fn cli_binary_smoke() {
    // The launcher must respond to `catalog` without artifacts or input
    // files (checks flag parsing + Table 1 rendering end to end).
    let exe = env!("CARGO_BIN_EXE_agora");
    let out = std::process::Command::new(exe)
        .arg("catalog")
        .output()
        .expect("binary runs");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("m5.4xlarge"));
    assert!(text.contains("96 candidates"));
}

#[test]
fn cli_optimize_builtin_dag() {
    let exe = env!("CARGO_BIN_EXE_agora");
    let out = std::process::Command::new(exe)
        .args(["optimize", "fig1", "--goal", "balanced", "--max-iters", "100", "--seed", "3"])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("predicted makespan"));
    assert!(text.contains("index-analysis"));
}
