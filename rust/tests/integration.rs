//! Cross-layer integration tests: AOT artifacts (L1/L2) executed through
//! the PJRT runtime must agree with the host-side predictor, and the
//! full Predictor -> Problem -> co-optimize -> execute chain must hold
//! together. Requires `make artifacts` (skips cleanly when absent).

use std::path::PathBuf;

use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::workloads::{dag1, ALL_JOBS};
use agora::predictor::{bootstrap_history, default_profiling_configs, EventLog};
use agora::runtime::{ArtifactManifest, Engine, PjrtPredictor};
use agora::solver::{Agora, AgoraOptions, Goal};
use agora::util::Rng;
use agora::{LearnedPredictor, Predictor};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = ArtifactManifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT integration test: run `make artifacts` first");
        None
    }
}

fn sample_logs(seed: u64) -> Vec<EventLog> {
    let mut rng = Rng::new(seed);
    ALL_JOBS
        .iter()
        .map(|j| bootstrap_history(j.name(), &j.profile(), &default_profiling_configs(), &mut rng))
        .collect()
}

#[test]
fn pjrt_predict_matches_host_predictor() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let space = ConfigSpace::standard();
    let host = LearnedPredictor::fit(&sample_logs(1));
    let host_grid = host.predict(&space);
    let pjrt_grid = PjrtPredictor::new(&engine)
        .predict_fitted(&host.fits, &space)
        .expect("pjrt predict");

    assert_eq!(pjrt_grid.tasks(), host_grid.tasks());
    for t in 0..host_grid.tasks() {
        for c in 0..space.len() {
            let h = host_grid.get(t, c);
            let x = pjrt_grid.get(t, c);
            assert!(
                (h - x).abs() / h.max(1e-9) < 1e-4,
                "task {t} config {c}: host {h} vs pjrt {x}"
            );
        }
    }
}

#[test]
fn pjrt_fit_predict_matches_host_fit() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let space = ConfigSpace::standard();
    let logs = sample_logs(2);

    let host = LearnedPredictor::fit(&logs);
    let host_grid = host.predict(&space);
    let (pjrt_grid, fits) = PjrtPredictor::new(&engine)
        .fit_predict(&logs, &space)
        .expect("pjrt fit_predict");

    // The device NNLS runs the same projected-gradient algorithm in f32;
    // theta agrees to f32 tolerance, grids to a slightly looser bound.
    assert_eq!(fits.len(), host.fits.len());
    for (hf, xf) in host.fits.iter().zip(fits.iter()) {
        for k in 0..agora::predictor::K {
            let h = hf.theta[k];
            let x = xf.theta[k];
            assert!(
                (h - x).abs() <= 1e-2 * h.abs().max(1.0),
                "theta[{k}]: host {h} vs pjrt {x}"
            );
        }
    }
    for t in 0..host_grid.tasks() {
        for c in 0..space.len() {
            let h = host_grid.get(t, c);
            let x = pjrt_grid.get(t, c);
            assert!(
                (h - x).abs() / h.max(1e-9) < 5e-3,
                "grid[{t}][{c}]: host {h} vs pjrt {x}"
            );
        }
    }
}

#[test]
fn pjrt_grid_drives_cooptimization_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let space = ConfigSpace::standard();
    let dags = vec![dag1()];
    let mut rng = Rng::new(3);
    let logs: Vec<EventLog> = dags[0]
        .tasks
        .iter()
        .map(|t| bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), &mut rng))
        .collect();
    let (grid, _) = PjrtPredictor::new(&engine)
        .fit_predict(&logs, &space)
        .expect("grid");

    let p = Agora::build_problem_with_grid(
        &dags,
        &[0.0],
        grid,
        Capacity::micro(),
        space,
        CostModel::OnDemand,
    );
    let plan = Agora::new(AgoraOptions {
        goal: Goal::Balanced,
        params: agora::solver::AnnealParams::fast(),
        ..Default::default()
    })
    .optimize(&p);
    plan.schedule.validate(&p).expect("valid plan");

    let report = agora::sim::execute(&p, &dags, &plan.schedule, &CostModel::OnDemand, &mut rng);
    assert!(report.makespan > 0.0 && report.cost > 0.0);
    assert!(
        report.prediction_mape < 0.5,
        "prediction error too high: {}",
        report.prediction_mape
    );
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    assert_eq!(engine.cached(), 0);
    let _ = engine.executable("predict_small").expect("compile");
    assert_eq!(engine.cached(), 1);
    let _ = engine.executable("predict_small").expect("cached");
    assert_eq!(engine.cached(), 1);
    let err = engine.executable("nonexistent");
    assert!(err.is_err());
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = ArtifactManifest::load(&dir).expect("manifest");
    for name in [
        "predict_small",
        "predict_large",
        "fit_predict_small",
        "fit_predict_large",
    ] {
        assert!(
            manifest.entries.contains_key(name),
            "missing artifact {name}"
        );
        assert!(dir.join(format!("{name}.hlo.txt")).exists());
    }
    assert_eq!(manifest.k, agora::predictor::K);
}

#[test]
fn large_task_counts_chunk_across_kernel_calls() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let space = ConfigSpace::standard();
    // 60 tasks x 3 presets = 180 rows > the large variant's 128: forces
    // at least two kernel calls through the chunking path.
    let mut rng = Rng::new(4);
    let logs: Vec<EventLog> = (0..60)
        .map(|i| {
            let p = agora::dag::generator::random_profile(&mut rng);
            bootstrap_history(&format!("t{i}"), &p, &default_profiling_configs(), &mut rng)
        })
        .collect();
    let host = LearnedPredictor::fit(&logs);
    let pjrt_grid = PjrtPredictor::new(&engine)
        .predict_fitted(&host.fits, &space)
        .expect("chunked predict");
    let host_grid = host.predict(&space);
    assert_eq!(pjrt_grid.tasks(), 60);
    for t in 0..60 {
        for c in 0..space.len() {
            let h = host_grid.get(t, c);
            let x = pjrt_grid.get(t, c);
            assert!(
                (h - x).abs() / h.max(1e-9) < 1e-4,
                "chunked grid[{t}][{c}]: {h} vs {x}"
            );
        }
    }
}
