//! Heterogeneous-market / spot-preemption test suite.
//!
//! The headline here is the **differential cost-model test**: the
//! executor's realized spot interruption process, Monte-Carlo'd over
//! many seeds, must converge to the closed-form expectation the planner
//! prices with (`CostModel::Spot` / `CostModel::Market` +
//! `expected_spot_overhead`). That closed form was fixed in this PR —
//! the historical `min(E[N], 2)` overhead over-charges near the
//! 2-interruption cap (Jensen); the tests below both pin the corrected
//! form against the realized process *and* assert the old form is
//! measurably wrong at the cap, so the fix cannot silently regress.

use agora::cluster::{
    catalog, expected_spot_overhead, Capacity, Config, ConfigSpace, CostModel,
};
use agora::dag::{Dag, Task, TaskProfile};
use agora::predictor::OraclePredictor;
use agora::sim::{execute_with_policy, DivergenceSpec, ReplanPolicy};
use agora::solver::{Problem, Schedule};
use agora::util::Rng;
use agora::Predictor;

/// One deterministic task (no run noise, no contention): nominal runtime
/// on a 1-node 16-vCPU instance is exactly `work` seconds.
fn one_task_dag(work: f64) -> Dag {
    Dag::new(
        "spot",
        vec![Task {
            name: "t".into(),
            profile: TaskProfile {
                work,
                alpha: 0.0,
                beta: 0.0,
                mem_gb: 4.0,
                spark_affinity: 0.0,
                noise_sigma: 0.0,
            },
        }],
        vec![],
    )
    .unwrap()
}

fn one_task_problem(work: f64, space: ConfigSpace, model: CostModel) -> (Problem, Vec<Dag>) {
    let dags = vec![one_task_dag(work)];
    let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let p = Problem::new(&dags, &[0.0], Capacity::new(64.0, 256.0), space, grid, model);
    (p, dags)
}

fn manual_single(p: &Problem, config: usize) -> Schedule {
    let s = Schedule {
        assignment: vec![config],
        start: vec![0.0],
        optimal: false,
    };
    s.validate(p).expect("single-task plan");
    s
}

/// Mean realized cost of executing the single-task plan under the spot
/// process with `runs` independent divergence seeds.
fn monte_carlo_mean_cost(
    p: &Problem,
    dags: &[Dag],
    plan: &Schedule,
    model: &CostModel,
    spot_rate: f64,
    runs: u64,
) -> f64 {
    let mut total = 0.0;
    for seed in 0..runs {
        let policy = ReplanPolicy {
            divergence: DivergenceSpec {
                spot_rate,
                seed: 0x1000 + seed,
                ..Default::default()
            },
            ..ReplanPolicy::off()
        };
        let report =
            execute_with_policy(p, dags, plan, model, &mut Rng::new(7), &policy);
        total += report.cost;
    }
    total / runs as f64
}

// ---------------------------------------------------------------------------
// Differential test, global-Spot flavour: everything is spot capacity.

#[test]
fn realized_spot_cost_converges_to_the_fixed_closed_form() {
    // One hour of work at 3 interruptions/node-hour: lambda = 3, deep
    // enough past the cap that E[min(N, 2)] = 1.751 differs measurably
    // from the historical min(E[N], 2) = 2.
    let work = 3600.0;
    let rate = 3.0;
    let model = CostModel::Spot {
        discount: 0.3,
        interrupt_rate: rate,
    };
    let (p, dags) = one_task_problem(work, ConfigSpace::standard(), model.clone());
    // 1 x m5.4xlarge, balanced preset: nominal runtime = work exactly.
    let cfg_idx = p
        .space
        .configs
        .iter()
        .position(|c| c.instance == 0 && c.nodes == 1 && c.spark == 1)
        .unwrap();
    let plan = manual_single(&p, cfg_idx);
    let cfg = p.space.configs[cfg_idx];

    let runs = 2500;
    let mean = monte_carlo_mean_cost(&p, &dags, &plan, &model, rate, runs);

    // The planner's closed form for the same (config, nominal duration).
    let closed = model.cost(&cfg, work);
    let rel = (mean - closed).abs() / closed;
    assert!(
        rel < 0.025,
        "realized mean {mean} vs closed form {closed} (rel {rel:.4})"
    );

    // ...and the historical uncapped-expectation form is measurably
    // wrong here: it would charge a full 2-interruption overhead.
    let old_form = cfg.hourly_cost() * 0.3 * (work * 2.0) / 3600.0;
    let rel_old = (mean - old_form).abs() / old_form;
    assert!(
        rel_old > 0.03,
        "realized mean {mean} indistinguishable from the broken closed form {old_form}"
    );
}

#[test]
fn realized_spot_cost_matches_closed_form_below_the_cap() {
    // Small lambda (0.25): the cap is irrelevant and the fixed form is
    // within noise of the historical one — this pins the small-rate
    // regime the original model was built for.
    let work = 1800.0;
    let rate = 0.5; // lambda = 0.5 * 1800 / 3600 = 0.25
    let model = CostModel::Spot {
        discount: 0.4,
        interrupt_rate: rate,
    };
    let (p, dags) = one_task_problem(work, ConfigSpace::standard(), model.clone());
    let cfg_idx = p
        .space
        .configs
        .iter()
        .position(|c| c.instance == 0 && c.nodes == 1 && c.spark == 1)
        .unwrap();
    let plan = manual_single(&p, cfg_idx);
    let cfg = p.space.configs[cfg_idx];

    let mean = monte_carlo_mean_cost(&p, &dags, &plan, &model, rate, 2500);
    let closed = model.cost(&cfg, work);
    let rel = (mean - closed).abs() / closed;
    assert!(
        rel < 0.02,
        "realized mean {mean} vs closed form {closed} (rel {rel:.4})"
    );
}

// ---------------------------------------------------------------------------
// Differential test, market flavour: the planner's inflated spot grid IS
// the realized expectation (grid inflation + catalog price coherence).

#[test]
fn realized_market_cost_converges_to_the_planners_spot_expectation() {
    let work = 3600.0;
    let rate = 1.5; // lambda = 1.5 on the 1-node spot row
    let model = CostModel::Market {
        interrupt_rate: rate,
    };
    let (p, dags) = one_task_problem(work, ConfigSpace::market(), model.clone());
    let spot_instance = catalog::index_by_name("m5.4xlarge:spot").unwrap();
    let cfg_idx = p
        .space
        .configs
        .iter()
        .position(|c| c.instance == spot_instance && c.nodes == 1 && c.spark == 1)
        .unwrap();
    let plan = manual_single(&p, cfg_idx);
    let cfg = p.space.configs[cfg_idx];

    let mean = monte_carlo_mean_cost(&p, &dags, &plan, &model, rate, 2500);

    // p.cost already prices the inflated grid duration at the catalog
    // spot price — planner expectation == realized mean.
    let planned = p.cost(0, cfg_idx);
    let rel = (mean - planned).abs() / planned;
    assert!(
        rel < 0.03,
        "realized mean {mean} vs planned spot cost {planned} (rel {rel:.4})"
    );
    // Sanity on the inflation itself: duration carries the overhead...
    let overhead = expected_spot_overhead(agora::cluster::spot_lambda(&cfg, work, rate));
    assert!((p.duration(0, cfg_idx) - work * overhead).abs() < 1e-9);
    // ...and the planned cost is exactly price x inflated duration.
    assert!(
        (planned - cfg.hourly_cost() * work * overhead / 3600.0).abs() < 1e-12,
        "planned {planned}"
    );
}

// ---------------------------------------------------------------------------
// Market-structure pins.

#[test]
fn on_demand_only_plans_never_see_preemptions() {
    // Spot divergence armed, but the plan holds an on-demand row under
    // Market pricing: the interruption process must not fire.
    let (p, dags) = one_task_problem(
        1800.0,
        ConfigSpace::market(),
        CostModel::Market { interrupt_rate: 2.0 },
    );
    let od_idx = p
        .space
        .configs
        .iter()
        .position(|c| c.instance == 0 && c.nodes == 1 && c.spark == 1)
        .unwrap();
    let plan = manual_single(&p, od_idx);
    let policy = ReplanPolicy {
        divergence: DivergenceSpec {
            spot_rate: 2.0,
            seed: 4242,
            ..Default::default()
        },
        ..ReplanPolicy::off()
    };
    let model = CostModel::Market { interrupt_rate: 2.0 };
    let report = execute_with_policy(&p, &dags, &plan, &model, &mut Rng::new(1), &policy);
    assert_eq!(report.records[0].preemptions, 0);
    assert!((report.records[0].runtime - 1800.0).abs() < 1e-9);
    // On-demand m5 price, plain occupancy.
    assert!((report.cost - 0.768 * 1800.0 / 3600.0).abs() < 1e-9);
}

#[test]
fn spot_rows_undercut_their_on_demand_twins_at_any_rate() {
    // The market's core structure: the expected re-run overhead is
    // capped at 2x (the preemption fallback), and every catalog spot
    // discount is >= 60% off, so discount x overhead < 1 for EVERY
    // rate — spot is always priced below its on-demand twin, and the
    // optimizer's spot-vs-on-demand choice is about runtime risk
    // (inflated durations), never about spot becoming nominally
    // pricier. Pinned at a moderate and a saturating rate.
    let (p, _) = one_task_problem(
        3600.0,
        ConfigSpace::market(),
        CostModel::Market { interrupt_rate: 0.5 },
    );
    for (od_name, spot_name) in [
        ("m5.4xlarge", "m5.4xlarge:spot"),
        ("c5.4xlarge", "c5.4xlarge:spot"),
        ("r5.4xlarge", "r5.4xlarge:spot"),
    ] {
        let od_i = catalog::index_by_name(od_name).unwrap();
        let spot_i = catalog::index_by_name(spot_name).unwrap();
        let find = |instance: usize| {
            p.space
                .configs
                .iter()
                .position(|c| c.instance == instance && c.nodes == 1 && c.spark == 1)
                .unwrap()
        };
        let od_cost = p.cost(0, find(od_i));
        let spot_cost = p.cost(0, find(spot_i));
        assert!(
            spot_cost < od_cost,
            "{spot_name} ({spot_cost}) should undercut {od_name} ({od_cost}) at rate 0.5"
        );
    }
    // r5's 75% discount survives even a saturating interruption rate.
    let (p_hot, _) = one_task_problem(
        3600.0,
        ConfigSpace::market(),
        CostModel::Market { interrupt_rate: 100.0 },
    );
    let od = catalog::index_by_name("r5.4xlarge").unwrap();
    let spot = catalog::index_by_name("r5.4xlarge:spot").unwrap();
    let find = |instance: usize| {
        p_hot
            .space
            .configs
            .iter()
            .position(|c| c.instance == instance && c.nodes == 1 && c.spark == 1)
            .unwrap()
    };
    assert!(p_hot.cost(0, find(spot)) < p_hot.cost(0, find(od)));
}

#[test]
fn preemption_process_is_per_node_scaled() {
    // Bigger gangs are exposed to more reclaim events: with the same
    // rate and nominal runtime, the 4-node spot config must average
    // more preemptions than the 1-node one over many seeds.
    let spec_for = |seed| DivergenceSpec {
        spot_rate: 1.0,
        seed,
        ..Default::default()
    };
    let mean_hits = |nodes: f64| -> f64 {
        let mut total = 0u32;
        for seed in 0..400u64 {
            let (_, hits) = spec_for(seed).draw_spot(0, true, nodes, 1800.0);
            total += hits;
        }
        total as f64 / 400.0
    };
    let small = mean_hits(1.0);
    let large = mean_hits(4.0);
    // lambda 0.5 vs 2.0: E[min(N,2)] = 0.39 vs 1.46 — far apart.
    assert!(
        large > small + 0.5,
        "4-node gang ({large}) should see many more preemptions than 1-node ({small})"
    );
}

#[test]
fn market_space_and_catalog_are_coherent() {
    let market = ConfigSpace::market();
    // Every catalog row appears on the full ladder with all presets.
    assert_eq!(
        market.len(),
        agora::cluster::FULL_CATALOG.len()
            * agora::cluster::config::NODE_LADDER.len()
            * agora::cluster::SPARK_PRESETS.len()
    );
    // The m5 prefix preserves historical indices: the standard space is
    // exactly the instance < 4 slice of the market space's catalog.
    let standard = ConfigSpace::standard();
    for c in &standard.configs {
        assert!(c.instance < 4);
        assert!(market.configs.contains(c));
    }
    // Spot rows all have a purchase toggle back to on-demand, and vice
    // versa for the listed sizes.
    for (i, row) in agora::cluster::FULL_CATALOG.iter().enumerate() {
        if row.is_spot() {
            let od = catalog::purchase_toggle(i).expect("spot rows have od twins");
            assert!(!agora::cluster::FULL_CATALOG[od].is_spot());
        }
    }
    // A Config's convenience accessors agree with its catalog row.
    let spot = Config {
        instance: catalog::index_by_name("r5.16xlarge:spot").unwrap(),
        nodes: 1,
        spark: 0,
    };
    assert!(spot.is_spot());
    assert_eq!(spot.vcpus(), 64.0);
    assert_eq!(spot.memory_gb(), 512.0);
}
