//! Property-based invariant tests across the whole scheduling stack
//! (in-repo `propcheck` harness; every failure message carries a replay
//! seed).
//!
//! Invariants:
//!   * every scheduler's output satisfies Eq. 2-5 (precedence, capacity,
//!     release, assignment validity) on arbitrary DAGs;
//!   * makespans never beat the problem lower bound;
//!   * the co-optimizer never returns worse Eq.-1 energy than its own
//!     baseline; budget-constrained runs respect budgets;
//!   * the execution simulator preserves precedence/capacity under
//!     actual (noisy) runtimes;
//!   * mid-flight re-planning stays feasible at arbitrary replan points:
//!     precedence/capacity hold end-to-end, no task executes twice, and
//!     records committed before a replan are immutable;
//!   * trigger policy batching covers every submission exactly once.

use agora::baselines::{
    AirflowScheduler, CriticalPathScheduler, ErnestGoal, MilpScheduler, Scheduler,
    StratusScheduler,
};
use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::generator::{arbitrary_dag, fig10_batch};
use agora::predictor::{bootstrap_history, default_profiling_configs, EventLog, OraclePredictor};
use agora::sim::{execute_with_policy, DivergenceSpec, ExecutionReport, ReplanPolicy};
use agora::solver::{Agora, AgoraOptions, AnnealParams, Goal, Mode, Problem};
use agora::util::{propcheck, Rng};
use agora::{Dag, Predictor};

fn oracle_problem(dags: Vec<Dag>, cap: Capacity) -> Problem {
    let space = ConfigSpace::standard();
    let profiles: Vec<_> = dags
        .iter()
        .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
        .collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let releases = vec![0.0; dags.len()];
    Problem::new(&dags, &releases, cap, space, grid, CostModel::OnDemand)
}

fn learned_problem(dags: Vec<Dag>, rng: &mut Rng) -> Problem {
    let space = ConfigSpace::standard();
    let logs: Vec<EventLog> = dags
        .iter()
        .flat_map(|d| {
            d.tasks
                .iter()
                .map(|t| bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), rng))
                .collect::<Vec<_>>()
        })
        .collect();
    let releases = vec![0.0; dags.len()];
    Agora::build_problem(
        &dags,
        &releases,
        &logs,
        Capacity::micro(),
        space,
        CostModel::OnDemand,
    )
}

#[test]
fn all_schedulers_valid_on_arbitrary_dags() {
    propcheck::check(25, |rng| {
        let dag = arbitrary_dag(rng, 12);
        let p = oracle_problem(vec![dag], Capacity::micro());
        let goal = *rng.choice(&[Goal::Cost, Goal::Balanced, Goal::Runtime]);
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(AirflowScheduler::default()),
            Box::new(CriticalPathScheduler::with_ernest(ErnestGoal(goal))),
            Box::new(MilpScheduler::with_ernest(ErnestGoal(goal))),
            Box::new(StratusScheduler::default()),
        ];
        for s in schedulers {
            let sched = s
                .schedule(&p)
                .map_err(|e| format!("{}: {e:#}", s.name()))?;
            sched
                .validate(&p)
                .map_err(|e| format!("{}: {e}", s.name()))?;
            let lb = p.lower_bound(&sched.assignment);
            if sched.makespan(&p) + 1e-6 < lb {
                return Err(format!(
                    "{}: makespan {} beats lower bound {lb}",
                    s.name(),
                    sched.makespan(&p)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn timeline_kernels_agree_bit_for_bit_at_the_scheduler_level() {
    // Three-way differential across the kernel generations — the
    // block-indexed production `Timeline`, the retained flat sweep-line
    // (`timeline::flat`), and the historical rectangle list
    // (`timeline::reference`) — at the *scheduler* level: every priority
    // rule, random assignments, and a coin-flip occupancy seed so the
    // kernels must also agree when packing around reservations.
    use agora::solver::sgs::{self, Rule};
    use agora::solver::timeline::{flat, reference};
    propcheck::check(15, |rng| {
        let dag = arbitrary_dag(rng, 14);
        let mut p = oracle_problem(vec![dag], Capacity::micro());
        if rng.chance(0.5) {
            let cap = p.capacity;
            let s0 = rng.uniform(0.0, 500.0);
            let d0 = rng.uniform(1.0, 400.0);
            // Half-memory blocker: contends without making anything
            // infeasible, so all three kernels must thread through it.
            p = p.with_occupancy(vec![(s0, d0, cap.vcpus * 0.5, cap.memory_gb * 0.5)], 0.0);
        }
        let assignment: Vec<usize> = (0..p.len())
            .map(|_| p.feasible[rng.below(p.feasible.len())])
            .collect();
        let mut rules: Vec<Rule> = sgs::ALL_RULES.to_vec();
        rules.truncate(3); // 3 rules x 15 reps keeps the O(n³) reference affordable
        for rule in rules {
            let prio = sgs::priorities(&p, &assignment, rule);
            let idx = sgs::serial_sgs(&p, &assignment, &prio).map_err(|e| e.to_string())?;
            let fl = flat::serial_sgs_flat(&p, &assignment, &prio);
            let rf = reference::serial_sgs_ref(&p, &assignment, &prio);
            idx.validate(&p).map_err(|e| e.to_string())?;
            for t in 0..p.len() {
                if idx.start[t].to_bits() != fl.start[t].to_bits()
                    || idx.start[t].to_bits() != rf.start[t].to_bits()
                {
                    return Err(format!(
                        "{rule:?}: kernel divergence at task {t}: indexed {} flat {} rect {}",
                        idx.start[t], fl.start[t], rf.start[t]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn cooptimizer_schedules_valid_and_never_worse_than_baseline() {
    propcheck::check(8, |rng| {
        let dag = arbitrary_dag(rng, 10);
        let p = learned_problem(vec![dag], rng);
        let goal = *rng.choice(&[Goal::Cost, Goal::Balanced, Goal::Runtime]);
        let plan = Agora::new(AgoraOptions {
            goal,
            mode: Mode::CoOptimize,
            params: AnnealParams::fast(),
            seed: rng.next_u64(),
            ..Default::default()
        })
        .optimize(&p);
        plan.schedule.validate(&p).map_err(|e| e.to_string())?;

        // Energy of the plan must be <= 0 relative to the baseline the
        // optimizer itself measured (it can always keep the default).
        if let Some(a) = &plan.anneal {
            if a.energy > 1e-9 {
                return Err(format!("positive final energy {}", a.energy));
            }
        }
        Ok(())
    });
}

#[test]
fn budgets_are_respected_when_feasible() {
    propcheck::check(8, |rng| {
        let dag = arbitrary_dag(rng, 8);
        let p = learned_problem(vec![dag], rng);
        // Baseline to derive a satisfiable budget.
        let base = Agora::new(AgoraOptions {
            goal: Goal::Balanced,
            mode: Mode::SchedulerOnly,
            ..Default::default()
        })
        .optimize(&p);

        let plan = Agora::new(AgoraOptions {
            goal: Goal::Cost,
            mode: Mode::CoOptimize,
            params: AnnealParams::fast(),
            makespan_budget: base.makespan * 1.5,
            cost_budget: f64::INFINITY,
            seed: rng.next_u64(),
            ..Default::default()
        })
        .optimize(&p);
        if let Some(a) = &plan.anneal {
            if a.energy.is_finite() && plan.makespan > base.makespan * 1.5 + 1e-6 {
                return Err(format!(
                    "makespan {} exceeds budget {}",
                    plan.makespan,
                    base.makespan * 1.5
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn executor_preserves_invariants_under_noise() {
    propcheck::check(15, |rng| {
        let dags = fig10_batch(rng, 2);
        let p = oracle_problem(dags.clone(), Capacity::micro());
        let plan = Agora::new(AgoraOptions {
            mode: Mode::SchedulerOnly,
            ..Default::default()
        })
        .optimize(&p);
        let report = agora::sim::execute(&p, &dags, &plan.schedule, &CostModel::OnDemand, rng);

        // precedence under ACTUAL runtimes
        for &(a, b) in &p.precedence {
            let ra = &report.records[a];
            let rb = &report.records[b];
            if rb.start + 1e-6 < ra.start + ra.runtime {
                return Err(format!("task {b} started before predecessor {a} finished"));
            }
        }
        // capacity at every start event
        for r in &report.records {
            let at = r.start + 1e-9;
            let mut cpu = 0.0;
            let mut mem = 0.0;
            for o in &report.records {
                if o.start <= at && at < o.start + o.runtime {
                    cpu += p.space.configs[o.config].vcpus();
                    mem += p.space.configs[o.config].memory_gb();
                }
            }
            if cpu > p.capacity.vcpus + 1e-6 || mem > p.capacity.memory_gb + 1e-6 {
                return Err(format!("capacity exceeded at t={}", r.start));
            }
        }
        // all DAG completions positive, bounded by makespan
        for (d, &c) in report.dag_completion.iter().enumerate() {
            if c <= 0.0 || c > report.makespan + 1e-9 {
                return Err(format!("dag {d} completion {c} out of range"));
            }
        }
        Ok(())
    });
}

/// Shared feasibility check for executed reports: precedence and capacity
/// under realized times and final (possibly reassigned) configurations,
/// every task exactly once, records internally consistent.
fn check_execution_feasible(p: &Problem, report: &ExecutionReport) -> Result<(), String> {
    if report.records.len() != p.len() {
        return Err(format!(
            "{} tasks, {} records",
            p.len(),
            report.records.len()
        ));
    }
    let mut seen = vec![false; p.len()];
    for r in &report.records {
        if seen[r.task] {
            return Err(format!("task {} executed twice", r.task));
        }
        seen[r.task] = true;
        if !r.start.is_finite() || r.start < -1e-9 {
            return Err(format!("task {} has invalid start {}", r.task, r.start));
        }
        if !r.runtime.is_finite() || r.runtime <= 0.0 {
            return Err(format!("task {} has invalid runtime {}", r.task, r.runtime));
        }
        if !p.feasible.contains(&r.config) {
            return Err(format!("task {} ran on infeasible config {}", r.task, r.config));
        }
    }
    for &(a, b) in &p.precedence {
        let ra = &report.records[a];
        let rb = &report.records[b];
        if rb.start + 1e-6 < ra.start + ra.runtime {
            return Err(format!("task {b} started before predecessor {a} finished"));
        }
    }
    for r in &report.records {
        let at = r.start + 1e-9;
        let mut cpu = 0.0;
        let mut mem = 0.0;
        for o in &report.records {
            if o.start <= at && at < o.start + o.runtime {
                cpu += p.space.configs[o.config].vcpus();
                mem += p.space.configs[o.config].memory_gb();
            }
        }
        if cpu > p.capacity.vcpus + 1e-6 || mem > p.capacity.memory_gb + 1e-6 {
            return Err(format!("capacity exceeded at t={}", r.start));
        }
    }
    Ok(())
}

#[test]
fn replanning_executor_feasible_at_arbitrary_replan_points() {
    propcheck::check(12, |rng| {
        let dags = fig10_batch(rng, 2);
        let p = oracle_problem(dags.clone(), Capacity::micro());
        let plan = Agora::new(AgoraOptions {
            mode: Mode::SchedulerOnly,
            ..Default::default()
        })
        .optimize(&p);
        // Arbitrary trigger sensitivity, replan budget and divergence mix
        // -> replans fire at arbitrary points of the execution.
        let policy = ReplanPolicy {
            threshold: rng.uniform(0.02, 0.5),
            max_replans: rng.range(1, 3),
            iters: 40,
            seed: rng.next_u64(),
            divergence: DivergenceSpec {
                straggler_prob: rng.uniform(0.1, 0.5),
                straggler_factor: rng.uniform(2.0, 6.0),
                fail_prob: rng.uniform(0.0, 0.25),
                seed: rng.next_u64(),
                ..Default::default()
            },
            ..Default::default()
        };
        let report =
            execute_with_policy(&p, &dags, &plan.schedule, &CostModel::OnDemand, rng, &policy);
        check_execution_feasible(&p, &report)?;
        if report.replans.len() > policy.max_replans {
            return Err(format!(
                "{} replans exceed budget {}",
                report.replans.len(),
                policy.max_replans
            ));
        }
        for e in &report.replans {
            if e.divergence <= policy.threshold {
                return Err(format!(
                    "replan fired below threshold: {} <= {}",
                    e.divergence, policy.threshold
                ));
            }
            if !e.at.is_finite() || e.replanned == 0 {
                return Err("malformed replan provenance".into());
            }
        }
        Ok(())
    });
}

#[test]
fn replanning_never_rewrites_committed_records() {
    // Records completed before the first replan instant must be
    // bit-identical to the no-replan execution of the same divergent
    // world: re-planning reshapes the future, never history.
    propcheck::check(10, |rng| {
        let dags = fig10_batch(rng, 2);
        let p = oracle_problem(dags.clone(), Capacity::micro());
        let plan = Agora::new(AgoraOptions {
            mode: Mode::SchedulerOnly,
            ..Default::default()
        })
        .optimize(&p);
        let divergence = DivergenceSpec {
            straggler_prob: 0.35,
            straggler_factor: 5.0,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let stale_policy = ReplanPolicy {
            divergence: divergence.clone(),
            ..ReplanPolicy::off()
        };
        let replan_policy = ReplanPolicy {
            threshold: 0.1,
            max_replans: 2,
            iters: 40,
            seed: rng.next_u64(),
            divergence,
            ..Default::default()
        };
        let seed = rng.next_u64();
        let stale = execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &CostModel::OnDemand,
            &mut Rng::new(seed),
            &stale_policy,
        );
        let adapted = execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &CostModel::OnDemand,
            &mut Rng::new(seed),
            &replan_policy,
        );
        check_execution_feasible(&p, &adapted)?;
        let Some(first) = adapted.replans.first() else {
            return Ok(()); // never triggered: nothing to compare
        };
        for (a, b) in stale.records.iter().zip(adapted.records.iter()) {
            if b.start + b.runtime <= first.at - 1e-9
                && (a.start != b.start || a.runtime != b.runtime || a.config != b.config)
            {
                return Err(format!(
                    "replan rewrote committed task {}: ({}, {}, {}) -> ({}, {}, {})",
                    b.task, a.start, a.runtime, a.config, b.start, b.runtime, b.config
                ));
            }
        }
        Ok(())
    });
}

/// Market problem on the full heterogeneous space (m5/c5/r5 + spot) with
/// market pricing, for the spot-preemption properties.
fn market_problem(dags: Vec<Dag>, cap: Capacity, interrupt_rate: f64) -> Problem {
    let space = ConfigSpace::market();
    let profiles: Vec<_> = dags
        .iter()
        .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
        .collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let releases = vec![0.0; dags.len()];
    Problem::new(
        &dags,
        &releases,
        cap,
        space,
        grid,
        CostModel::Market { interrupt_rate },
    )
}

#[test]
fn spot_preemption_replanning_stays_feasible() {
    // Satellite pin: any seeded preemption sequence leaves every
    // post-replan schedule Eq.-4 feasible on the occupied timeline —
    // precedence and capacity hold end-to-end under realized times and
    // final (possibly reassigned) configurations, every preemption
    // count within the fallback cap, every replan within budget.
    propcheck::check(10, |rng| {
        let dags = fig10_batch(rng, 2);
        let p = market_problem(dags.clone(), Capacity::micro(), 1.0);
        // Cost-goal per-task-best + exact schedule: deterministic and
        // spot-heavy, so the preemption process has real targets.
        let plan = Agora::new(AgoraOptions {
            goal: Goal::Cost,
            mode: Mode::Separate,
            ..Default::default()
        })
        .optimize(&p);
        let spot_tasks = plan
            .schedule
            .assignment
            .iter()
            .filter(|&&c| p.space.configs[c].is_spot())
            .count();
        if spot_tasks == 0 {
            return Err("cost-goal market plan bought no spot capacity".into());
        }
        let policy = ReplanPolicy {
            threshold: rng.uniform(0.05, 0.4),
            max_replans: rng.range(1, 3),
            iters: 40,
            seed: rng.next_u64(),
            divergence: DivergenceSpec {
                spot_rate: rng.uniform(0.5, 4.0),
                seed: rng.next_u64(),
                ..Default::default()
            },
            ..Default::default()
        };
        let model = CostModel::Market { interrupt_rate: 1.0 };
        let report = execute_with_policy(&p, &dags, &plan.schedule, &model, rng, &policy);
        check_execution_feasible(&p, &report)?;
        for r in &report.records {
            if r.preemptions > policy.divergence.spot_max {
                return Err(format!(
                    "task {} charged {} preemptions past the cap {}",
                    r.task, r.preemptions, policy.divergence.spot_max
                ));
            }
        }
        if report.replans.len() > policy.max_replans {
            return Err(format!(
                "{} replans exceed budget {}",
                report.replans.len(),
                policy.max_replans
            ));
        }
        for e in &report.replans {
            if e.divergence <= policy.threshold {
                return Err(format!(
                    "replan fired below threshold: {} <= {}",
                    e.divergence, policy.threshold
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn spot_preemption_never_rewrites_committed_records() {
    // Satellite pin: records completed before the first
    // preemption-triggered replan are bit-identical to the no-replan
    // execution of the same preempted world — the same immutability
    // contract PR 2 established for stragglers/failures, now under
    // SpotPreemption divergence.
    propcheck::check(8, |rng| {
        let dags = fig10_batch(rng, 2);
        let p = market_problem(dags.clone(), Capacity::micro(), 1.0);
        let plan = Agora::new(AgoraOptions {
            goal: Goal::Cost,
            mode: Mode::Separate,
            ..Default::default()
        })
        .optimize(&p);
        let divergence = DivergenceSpec {
            spot_rate: rng.uniform(1.0, 4.0),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let stale_policy = ReplanPolicy {
            divergence: divergence.clone(),
            ..ReplanPolicy::off()
        };
        let replan_policy = ReplanPolicy {
            threshold: 0.1,
            max_replans: 2,
            iters: 40,
            seed: rng.next_u64(),
            divergence,
            ..Default::default()
        };
        let model = CostModel::Market { interrupt_rate: 1.0 };
        let seed = rng.next_u64();
        let stale = execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &model,
            &mut Rng::new(seed),
            &stale_policy,
        );
        let adapted = execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &model,
            &mut Rng::new(seed),
            &replan_policy,
        );
        check_execution_feasible(&p, &adapted)?;
        let Some(first) = adapted.replans.first() else {
            return Ok(()); // never triggered: nothing to compare
        };
        for (a, b) in stale.records.iter().zip(adapted.records.iter()) {
            if b.start + b.runtime <= first.at - 1e-9
                && (a.start != b.start
                    || a.runtime != b.runtime
                    || a.config != b.config
                    || a.preemptions != b.preemptions)
            {
                return Err(format!(
                    "replan rewrote committed task {}: ({}, {}, {}, {}) -> ({}, {}, {}, {})",
                    b.task,
                    a.start,
                    a.runtime,
                    a.config,
                    a.preemptions,
                    b.start,
                    b.runtime,
                    b.config,
                    b.preemptions
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn trigger_policy_batches_cover_all_submissions_once() {
    use agora::coordinator::{BatchRunner, Strategy};
    use agora::trace::{generate, TraceParams};
    propcheck::check(5, |rng| {
        let params = TraceParams {
            jobs: rng.range(3, 10),
            window: 3600.0,
            machines: 8,
            ..TraceParams::default()
        };
        let jobs = generate(&params, rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            rng.next_u64(),
        );
        let report = runner.run(&jobs).map_err(|e| e.to_string())?;
        if report.outcomes.len() != jobs.len() {
            return Err(format!(
                "{} jobs submitted, {} outcomes",
                jobs.len(),
                report.outcomes.len()
            ));
        }
        // each job appears exactly once and completion > 0
        let mut names: Vec<&str> = report.outcomes.iter().map(|o| o.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != jobs.len() {
            return Err("duplicate or missing DAG outcomes".into());
        }
        for o in &report.outcomes {
            if o.completion <= 0.0 {
                return Err(format!("{} has non-positive completion", o.name));
            }
            if o.finish_time + 1e-9 < o.submit_time {
                return Err(format!("{} finished before submission", o.name));
            }
        }
        Ok(())
    });
}

#[test]
fn hard_sla_plans_that_promise_the_deadline_deliver_it() {
    // Deadline-cost planning + zero-noise execution: whenever the plan
    // itself promises every hard deadline, the realized run (replanning
    // off, no divergence injected) delivers it. A plan that already
    // misses is the admission layer's domain (reject/defer), not this
    // invariant's — those draws are skipped.
    use agora::solver::Sla;
    propcheck::check(12, |rng| {
        let mut dag = arbitrary_dag(rng, 8);
        for t in dag.tasks.iter_mut() {
            t.profile.noise_sigma = 0.0;
        }
        let dags = vec![dag];
        let p = oracle_problem(dags.clone(), Capacity::micro());
        let lb = p.dag_lower_bounds()[0];
        let deadline = lb * rng.uniform(1.5, 3.0);
        let p = p.with_slas(vec![Sla::hard(deadline)]);

        let plan = Agora::new(AgoraOptions {
            goal: Goal::DeadlineCost,
            mode: Mode::CoOptimize,
            params: AnnealParams {
                max_iters: 80,
                patience: 80,
                ..AnnealParams::fast()
            },
            seed: rng.next_u64(),
            ..Default::default()
        })
        .optimize(&p);
        plan.schedule.validate(&p).map_err(|e| e.to_string())?;
        if plan.schedule.dag_completion(&p, 0) > deadline {
            return Ok(()); // planned miss: admission's reject/defer path
        }

        let report = execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &CostModel::OnDemand,
            &mut Rng::new(rng.next_u64()),
            &ReplanPolicy::off(),
        );
        if report.dag_completion[0] > deadline + 1e-6 {
            return Err(format!(
                "plan promised {deadline}, realized {} with no divergence",
                report.dag_completion[0]
            ));
        }
        Ok(())
    });
}

#[test]
fn admission_never_rejects_provably_feasible_dags() {
    // The admission layer's only provable-reject predicate is
    // Problem::sla_infeasible — a hard deadline below the release-aware
    // critical-path lower bound. Any deadline at or above that bound
    // must therefore never be flagged, whatever the DAG shape.
    use agora::solver::Sla;
    propcheck::check(20, |rng| {
        let dags = vec![arbitrary_dag(rng, 10), arbitrary_dag(rng, 6)];
        let p = oracle_problem(dags, Capacity::micro());
        let slas: Vec<Sla> = p
            .dag_lower_bounds()
            .iter()
            .map(|&lb| Sla::hard(lb * rng.uniform(1.0, 3.0)))
            .collect();
        let p = p.with_slas(slas);
        let flagged = p.sla_infeasible();
        if flagged.iter().any(|&x| x) {
            return Err(format!(
                "deadline >= lower bound flagged infeasible: {flagged:?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn sla_accounting_invariants_hold_on_random_traces() {
    // Macro-level bookkeeping, any policy knobs: every executed DAG is
    // counted exactly once as met or missed, penalties are non-negative
    // and exactly zero without misses (or without a penalty rate), and
    // rejection requires enforced hard SLAs.
    use agora::coordinator::{BatchRunner, SlaPolicy, Strategy};
    use agora::trace::{generate, TraceParams};
    propcheck::check(4, |rng| {
        let params = TraceParams::tiny();
        let jobs = generate(&params, rng);
        let policy = SlaPolicy {
            deadline_frac: rng.uniform(0.5, 2.5),
            penalty_per_sec: if rng.chance(0.5) { 0.0 } else { 0.05 },
            hard: rng.chance(0.5),
            enforce: rng.chance(0.5),
        };
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::AgoraMode(Goal::DeadlineCost, Mode::Separate),
            rng.next_u64(),
        )
        .with_sla(policy.clone());
        let report = runner.run(&jobs).map_err(|e| e.to_string())?;

        if report.sla_met + report.sla_missed != report.outcomes.len() {
            return Err(format!(
                "{} outcomes but {} met + {} missed",
                report.outcomes.len(),
                report.sla_met,
                report.sla_missed
            ));
        }
        if report.outcomes.len() + report.rejected != jobs.len() {
            return Err(format!(
                "{} jobs != {} executed + {} rejected",
                jobs.len(),
                report.outcomes.len(),
                report.rejected
            ));
        }
        if !(report.penalty_cost >= 0.0 && report.penalty_cost.is_finite()) {
            return Err(format!("bad penalty cost {}", report.penalty_cost));
        }
        if report.sla_missed == 0 && report.penalty_cost != 0.0 {
            return Err(format!(
                "no misses but penalty cost {}",
                report.penalty_cost
            ));
        }
        if policy.penalty_per_sec == 0.0 && report.penalty_cost != 0.0 {
            return Err(format!(
                "zero penalty rate accrued {}",
                report.penalty_cost
            ));
        }
        if !(policy.hard && policy.enforce) && report.rejected != 0 {
            return Err(format!(
                "{} rejections without enforced hard SLAs",
                report.rejected
            ));
        }
        Ok(())
    });
}

#[test]
fn per_task_best_is_locally_optimal() {
    use agora::solver::cooptimizer::per_task_best;
    propcheck::check(20, |rng| {
        let dag = arbitrary_dag(rng, 8);
        let p = oracle_problem(vec![dag], Capacity::micro());
        for goal in [Goal::Runtime, Goal::Cost] {
            let sel = per_task_best(&p, goal);
            for (t, &c) in sel.iter().enumerate() {
                for &other in &p.feasible {
                    let better = match goal {
                        Goal::Runtime => p.duration(t, other) + 1e-9 < p.duration(t, c),
                        Goal::Cost => p.cost(t, other) + 1e-9 < p.cost(t, c),
                        _ => false,
                    };
                    if better {
                        return Err(format!(
                            "task {t}: config {other} dominates chosen {c} for {goal:?}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}
