//! Golden-scenario regression corpus for the closed-loop executor.
//!
//! Each scenario pins one (DAG, cluster, divergence) combination with
//! zero-noise profiles and hand-built plans, so realized timelines are
//! exactly computable: the tests assert bit-identical determinism across
//! repeated runs with fixed seeds AND pin makespan/cost against
//! hand-derived references. The corpus is the contract the re-planning
//! subsystem must never drift from:
//!
//!   1. chain, no divergence      — closed-loop == open-loop == predicted
//!   2. diamond + pinned straggler, replanning off — exact stale makespan
//!   3. straggler + replanning    — replanning strictly beats the stale
//!                                  plan (the headline adaptation gain)
//!   4. pinned task failure       — one retry, bounded inflation
//!   5. capacity outage window    — execution packs around the lost slice
//!   6. seeded random multi-DAG   — bitwise determinism under
//!                                  probabilistic divergence + replans
//!   7. policy-off equivalence    — the event-driven executor reproduces
//!                                  the historical executor bit-for-bit
//!
//! Heterogeneous-market scenarios (instance families + spot capacity):
//!
//!   8. family flip               — the co-optimizer picks on-demand/c5
//!                                  under the runtime goal and spot
//!                                  (c5 for cpu-bound, r5 for
//!                                  memory-bound) under the cost goal;
//!                                  exact makespan/cost pins
//!   9. spot preemption replan    — a pinned preemption on a spot node
//!                                  triggers replanning; the cone task
//!                                  flips family; exact pins incl.
//!                                  realized spot cost
//!  10. seeded spot market batch  — bitwise determinism of a seeded
//!                                  preemption process with replanning
//!                                  armed on the market space
//!  11. deadline-at-risk flip     — a pinned spot preemption pushes a
//!                                  hard-SLA DAG past its deadline; the
//!                                  SLA-aware policy migrates exactly
//!                                  the at-risk cone to on-demand c5
//!                                  and meets the deadline, with exact
//!                                  makespan/cost pins; the SLA-blind
//!                                  policy provably misses

use agora::cluster::{catalog, Capacity, Config, ConfigSpace, CostModel, Family};
use agora::dag::generator::arbitrary_dag;
use agora::dag::{Dag, Task, TaskProfile};
use agora::predictor::OraclePredictor;
use agora::sim::{
    execute, execute_with_policy, CapacityOutage, DivergenceSpec, ExecutionReport,
    ReplanPolicy,
};
use agora::solver::{Agora, AgoraOptions, Goal, Mode, Problem, Schedule, Sla};
use agora::util::Rng;
use agora::Predictor;

/// Deterministic profile: zero noise, zero contention, tiny working set —
/// realized runtime at `nodes` x m5.4xlarge (balanced preset) is exactly
/// `work / n_eff`.
fn exact_profile(work: f64) -> TaskProfile {
    TaskProfile {
        work,
        alpha: 0.0,
        beta: 0.0,
        mem_gb: 4.0,
        spark_affinity: 0.0,
        noise_sigma: 0.0,
    }
}

fn exact_task(name: &str, work: f64) -> Task {
    Task {
        name: name.to_string(),
        profile: exact_profile(work),
    }
}

fn oracle_problem(dags: &[Dag], capacity: Capacity) -> Problem {
    let space = ConfigSpace::standard();
    let profiles: Vec<_> = dags
        .iter()
        .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
        .collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let releases = vec![0.0; dags.len()];
    Problem::new(dags, &releases, capacity, space, grid, CostModel::OnDemand)
}

/// Index of `nodes` x m5.4xlarge with the balanced Spark preset.
fn m5_4xl(space: &ConfigSpace, nodes: u32) -> usize {
    space
        .configs
        .iter()
        .position(|c| c.instance == 0 && c.nodes == nodes && c.spark == 1)
        .expect("standard space carries the m5.4xlarge ladder")
}

/// A two-wide cluster: exactly two 1 x m5.4xlarge tasks fit side by side.
fn two_wide() -> Capacity {
    Capacity::new(32.0, 128.0)
}

fn manual_plan(p: &Problem, config: usize, starts: &[f64]) -> Schedule {
    let s = Schedule {
        assignment: vec![config; p.len()],
        start: starts.to_vec(),
        optimal: false,
    };
    s.validate(p).expect("pinned plans are valid by construction");
    s
}

fn assert_reports_bit_identical(a: &ExecutionReport, b: &ExecutionReport) {
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(x.task, y.task);
        assert_eq!(x.config, y.config);
        assert!(x.start == y.start, "start {} != {}", x.start, y.start);
        assert!(x.runtime == y.runtime, "runtime {} != {}", x.runtime, y.runtime);
        assert!(x.predicted == y.predicted);
        assert_eq!(x.retries, y.retries);
        assert_eq!(x.preemptions, y.preemptions);
    }
    assert!(a.makespan == b.makespan);
    assert!(a.cost == b.cost);
    assert!(a.prediction_mape == b.prediction_mape);
    assert_eq!(a.dag_completion.len(), b.dag_completion.len());
    for (x, y) in a.dag_completion.iter().zip(b.dag_completion.iter()) {
        assert!(x == y);
    }
    assert_eq!(a.replans.len(), b.replans.len());
    for (x, y) in a.replans.iter().zip(b.replans.iter()) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.trigger_task, y.trigger_task);
        assert!(x.at == y.at);
        assert!(x.divergence == y.divergence);
        assert_eq!(x.replanned, y.replanned);
        assert_eq!(x.reassigned, y.reassigned);
    }
}

// ---------------------------------------------------------------------------
// 1. Chain, no divergence: closed-loop == open-loop == predicted.

#[test]
fn scenario_chain_baseline_matches_prediction_exactly() {
    let dag = Dag::new(
        "chain",
        vec![exact_task("x", 20.0), exact_task("y", 30.0), exact_task("z", 10.0)],
        vec![(0, 1), (1, 2)],
    )
    .unwrap();
    let p = oracle_problem(std::slice::from_ref(&dag), two_wide());
    let c1 = m5_4xl(&p.space, 1);
    let plan = manual_plan(&p, c1, &[0.0, 20.0, 50.0]);

    let run = |seed| {
        execute_with_policy(
            &p,
            &[dag.clone()],
            &plan,
            &CostModel::OnDemand,
            &mut Rng::new(seed),
            &ReplanPolicy::off(),
        )
    };
    let a = run(1);
    let b = run(1);
    assert_reports_bit_identical(&a, &b);

    // Zero noise: realized == predicted, to the last bit of arithmetic.
    assert!((a.makespan - 60.0).abs() < 1e-9, "makespan {}", a.makespan);
    let expected_cost = 0.768 * 60.0 / 3600.0;
    assert!((a.cost - expected_cost).abs() < 1e-9, "cost {}", a.cost);
    assert!(a.prediction_mape < 1e-9, "mape {}", a.prediction_mape);
    assert!(a.replans.is_empty());
}

// ---------------------------------------------------------------------------
// 2. Diamond + pinned straggler, replanning off: exact stale makespan.

fn diamond() -> Dag {
    Dag::new(
        "diamond",
        vec![
            exact_task("a", 10.0),
            exact_task("b", 10.0),
            exact_task("c", 10.0),
            exact_task("d", 10.0),
        ],
        vec![(0, 1), (0, 2), (1, 3), (2, 3)],
    )
    .unwrap()
}

#[test]
fn scenario_diamond_straggler_stale_plan_pinned() {
    let dag = diamond();
    let p = oracle_problem(std::slice::from_ref(&dag), two_wide());
    let c1 = m5_4xl(&p.space, 1);
    let plan = manual_plan(&p, c1, &[0.0, 10.0, 10.0, 20.0]);
    let policy = ReplanPolicy {
        divergence: DivergenceSpec {
            straggler_tasks: vec![1],
            straggler_factor: 3.0,
            ..Default::default()
        },
        ..ReplanPolicy::off()
    };
    let run = |seed| {
        execute_with_policy(
            &p,
            &[dag.clone()],
            &plan,
            &CostModel::OnDemand,
            &mut Rng::new(seed),
            &policy,
        )
    };
    let a = run(2);
    assert_reports_bit_identical(&a, &run(2));

    // Hand timeline: a 0-10, b (straggles x3) 10-40, c 10-20, d 40-50.
    assert!((a.records[0].end() - 10.0).abs() < 1e-9);
    assert!((a.records[1].runtime - 30.0).abs() < 1e-9);
    assert!((a.records[2].end() - 20.0).abs() < 1e-9);
    assert!((a.records[3].start - 40.0).abs() < 1e-9);
    assert!((a.makespan - 50.0).abs() < 1e-9, "makespan {}", a.makespan);
}

// ---------------------------------------------------------------------------
// 3. The headline: replanning strictly beats the stale plan.

/// Four tasks on the two-wide cluster: a (straggles x3), independent b
/// and d, and c depending on a. The stale plan holds c on the 1-node
/// config and realizes makespan 40; a replan triggered by a's divergent
/// completion at t=30 reassigns c to the 2-node config (5 s instead of
/// 10 s on the now-empty cluster) and realizes 35.
fn straggler_scenario() -> (Problem, Vec<Dag>, Schedule) {
    let dag = Dag::new(
        "replan-win",
        vec![
            exact_task("a", 10.0),
            exact_task("b", 10.0),
            exact_task("c", 10.0),
            exact_task("d", 12.0),
        ],
        vec![(0, 2)],
    )
    .unwrap();
    let dags = vec![dag];
    let p = oracle_problem(&dags, two_wide());
    let c1 = m5_4xl(&p.space, 1);
    let plan = manual_plan(&p, c1, &[0.0, 0.0, 10.0, 10.0]);
    (p, dags, plan)
}

#[test]
fn scenario_replanning_strictly_beats_stale_plan_under_straggler() {
    let (p, dags, plan) = straggler_scenario();
    let divergence = DivergenceSpec {
        straggler_tasks: vec![0],
        straggler_factor: 3.0,
        ..Default::default()
    };
    let stale_policy = ReplanPolicy {
        divergence: divergence.clone(),
        ..ReplanPolicy::off()
    };
    let replan_policy = ReplanPolicy {
        threshold: 0.2,
        max_replans: 2,
        iters: 120,
        divergence,
        ..Default::default()
    };

    let stale = execute_with_policy(
        &p,
        &dags,
        &plan,
        &CostModel::OnDemand,
        &mut Rng::new(3),
        &stale_policy,
    );
    let adapted = execute_with_policy(
        &p,
        &dags,
        &plan,
        &CostModel::OnDemand,
        &mut Rng::new(3),
        &replan_policy,
    );
    assert_reports_bit_identical(
        &adapted,
        &execute_with_policy(
            &p,
            &dags,
            &plan,
            &CostModel::OnDemand,
            &mut Rng::new(3),
            &replan_policy,
        ),
    );

    // Stale timeline: a 0-30, b 0-10, d 10-22 (backfilled), c 30-40.
    assert!((stale.makespan - 40.0).abs() < 1e-9, "stale {}", stale.makespan);
    assert!(stale.replans.is_empty());

    // Adapted: trigger at a's completion (t=30, divergence (30-10)/22),
    // cone = {c}, reassigned to 2 nodes -> c 30-35.
    assert_eq!(adapted.replans.len(), 1);
    let e = &adapted.replans[0];
    assert_eq!(e.round, 1);
    assert_eq!(e.trigger_task, 0);
    assert!((e.at - 30.0).abs() < 1e-9, "trigger at {}", e.at);
    assert!(e.divergence > 0.2);
    assert_eq!(e.replanned, 1);
    assert_eq!(e.reassigned, 1);
    assert!((adapted.makespan - 35.0).abs() < 1e-9, "adapted {}", adapted.makespan);
    assert!(
        adapted.makespan < stale.makespan - 1.0,
        "replanning must strictly improve realized makespan: {} vs {}",
        adapted.makespan,
        stale.makespan
    );
    // The 2-node reassignment halves the runtime at the same node-seconds:
    // adaptation here is cost-neutral.
    assert!(
        (adapted.cost - stale.cost).abs() < 1e-9,
        "cost drifted: {} vs {}",
        adapted.cost,
        stale.cost
    );
    // Replan provenance records the projected gain.
    assert!((e.stale_makespan - 40.0).abs() < 1e-9);
    assert!((e.planned_makespan - 35.0).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// 4. Pinned task failure: one retry, bounded inflation.

#[test]
fn scenario_pinned_failure_costs_one_bounded_retry() {
    let dag = Dag::new(
        "retry",
        vec![exact_task("x", 10.0), exact_task("y", 10.0)],
        vec![(0, 1)],
    )
    .unwrap();
    let p = oracle_problem(std::slice::from_ref(&dag), two_wide());
    let c1 = m5_4xl(&p.space, 1);
    let plan = manual_plan(&p, c1, &[0.0, 10.0]);
    let policy = ReplanPolicy {
        divergence: DivergenceSpec {
            fail_tasks: vec![0],
            seed: 40,
            ..Default::default()
        },
        ..ReplanPolicy::off()
    };
    let run = |seed| {
        execute_with_policy(
            &p,
            &[dag.clone()],
            &plan,
            &CostModel::OnDemand,
            &mut Rng::new(seed),
            &policy,
        )
    };
    let a = run(4);
    assert_reports_bit_identical(&a, &run(4));
    assert_eq!(a.records[0].retries, 1);
    assert_eq!(a.records[1].retries, 0);
    // Failure wastes 20-80% of an attempt: x in [12, 18), chain in [22, 28).
    assert!(a.records[0].runtime >= 12.0 - 1e-9 && a.records[0].runtime < 18.0 + 1e-9);
    assert!((a.records[1].start - a.records[0].end()).abs() < 1e-9);
    assert!(a.makespan >= 22.0 - 1e-9 && a.makespan < 28.0 + 1e-9);
}

// ---------------------------------------------------------------------------
// 5. Capacity outage: execution packs around the lost slice.

#[test]
fn scenario_capacity_outage_serializes_the_window() {
    let dag = Dag::new(
        "outage",
        vec![exact_task("e", 10.0), exact_task("f", 10.0)],
        vec![],
    )
    .unwrap();
    let p = oracle_problem(std::slice::from_ref(&dag), two_wide());
    let c1 = m5_4xl(&p.space, 1);
    let plan = manual_plan(&p, c1, &[0.0, 0.0]);

    // Baseline: both run side by side.
    let free = execute(
        &p,
        &[dag.clone()],
        &plan,
        &CostModel::OnDemand,
        &mut Rng::new(5),
    );
    assert!((free.makespan - 10.0).abs() < 1e-9);

    // Half the cluster is gone for [0, 20): only one task fits at a time.
    let policy = ReplanPolicy {
        divergence: DivergenceSpec {
            outage: Some(CapacityOutage {
                at: 0.0,
                duration: 20.0,
                cpu_fraction: 0.5,
                mem_fraction: 0.5,
            }),
            ..Default::default()
        },
        ..ReplanPolicy::off()
    };
    let run = |seed| {
        execute_with_policy(
            &p,
            &[dag.clone()],
            &plan,
            &CostModel::OnDemand,
            &mut Rng::new(seed),
            &policy,
        )
    };
    let a = run(5);
    assert_reports_bit_identical(&a, &run(5));
    assert!((a.records[0].start - 0.0).abs() < 1e-9);
    assert!((a.records[1].start - 10.0).abs() < 1e-9);
    assert!((a.makespan - 20.0).abs() < 1e-9, "makespan {}", a.makespan);
}

// ---------------------------------------------------------------------------
// 6. Seeded random multi-DAG: bitwise determinism under probabilistic
//    divergence with replanning armed.

#[test]
fn scenario_random_batch_with_replans_is_bitwise_deterministic() {
    let dags = vec![
        arbitrary_dag(&mut Rng::new(601), 10),
        arbitrary_dag(&mut Rng::new(602), 8),
    ];
    let p = oracle_problem(&dags, Capacity::micro());
    // Plan once (the inner CP solver has a wall-clock cutoff; execution
    // itself must be load-independent, which is what this scenario pins).
    let plan = Agora::new(AgoraOptions {
        mode: Mode::SchedulerOnly,
        ..Default::default()
    })
    .optimize(&p);
    let policy = ReplanPolicy {
        threshold: 0.1,
        max_replans: 2,
        iters: 60,
        seed: 606,
        divergence: DivergenceSpec {
            straggler_prob: 0.3,
            straggler_factor: 5.0,
            fail_prob: 0.15,
            seed: 607,
            ..Default::default()
        },
        ..Default::default()
    };
    let run = |seed| {
        execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &CostModel::OnDemand,
            &mut Rng::new(seed),
            &policy,
        )
    };
    let a = run(608);
    assert_reports_bit_identical(&a, &run(608));

    // Loose physical pins: the longest task bounds makespan below; each
    // execution phase (initial dispatch + one per replan floor) can add
    // at most one serial pass, bounding it above.
    let serial: f64 = a.records.iter().map(|r| r.runtime).sum();
    let longest = a.records.iter().map(|r| r.runtime).fold(0.0, f64::max);
    let phases = (policy.max_replans + 1) as f64;
    assert!(a.makespan <= serial * phases + 1e-6);
    assert!(a.makespan >= longest - 1e-6);
    assert!(a.cost > 0.0 && a.cost.is_finite());
    assert!(a.prediction_mape.is_finite());
}

// ---------------------------------------------------------------------------
// 7. Policy-off equivalence: the event-driven executor reproduces the
//    historical (pre-replanning) executor bit-for-bit.

/// The seed repo's executor, reimplemented verbatim against public APIs:
/// draw runtimes in flat order, dispatch in plan order with earliest-fit
/// over actual durations. Any behavioural drift in `execute` under an
/// off policy shows up as a mismatch here.
fn historical_execute(
    p: &Problem,
    dags: &[Dag],
    schedule: &Schedule,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<f64>, f64) {
    use agora::predictor::simulate_run;
    let n = p.len();
    let profiles: Vec<_> = p
        .tasks
        .iter()
        .map(|ft| dags[ft.dag].tasks[ft.local].profile.clone())
        .collect();
    let mut runtimes = Vec::with_capacity(n);
    for t in 0..n {
        let cfg = p.space.configs[schedule.assignment[t]];
        let (rt, _) = simulate_run(&profiles[t], cfg, rng);
        runtimes.push(rt);
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        schedule.start[a]
            .total_cmp(&schedule.start[b])
            .then(a.cmp(&b))
    });
    let mut timeline =
        agora::solver::Timeline::new(p.capacity.vcpus, p.capacity.memory_gb);
    let mut start = vec![f64::NAN; n];
    let mut placed = vec![false; n];
    let mut remaining = order;
    while !remaining.is_empty() {
        let pos = remaining
            .iter()
            .position(|&t| p.preds(t).iter().all(|&q| placed[q]))
            .expect("valid plans always have a dispatchable task");
        let t = remaining.remove(pos);
        let est = p
            .preds(t)
            .iter()
            .map(|&q| start[q] + runtimes[q])
            .fold(p.release[t], f64::max);
        let (cpu, mem) = p.demand(schedule.assignment[t]);
        let s = timeline
            .earliest_fit(est, runtimes[t], cpu, mem)
            .expect("planned configurations fit the cluster");
        timeline.place(s, runtimes[t], cpu, mem);
        start[t] = s;
        placed[t] = true;
    }
    let makespan = (0..n)
        .map(|t| start[t] + runtimes[t])
        .fold(0.0, f64::max);
    (start, runtimes, makespan)
}

#[test]
fn scenario_off_policy_reproduces_historical_executor_bitwise() {
    for (dag_seed, exec_seed) in [(701u64, 702u64), (703, 704), (705, 706)] {
        let dags = vec![
            arbitrary_dag(&mut Rng::new(dag_seed), 9),
            arbitrary_dag(&mut Rng::new(dag_seed + 10), 7),
        ];
        let p = oracle_problem(&dags, Capacity::micro());
        let plan = Agora::new(AgoraOptions {
            mode: Mode::SchedulerOnly,
            ..Default::default()
        })
        .optimize(&p);

        let (start, runtimes, makespan) =
            historical_execute(&p, &dags, &plan.schedule, &mut Rng::new(exec_seed));
        let report = execute(
            &p,
            &dags,
            &plan.schedule,
            &CostModel::OnDemand,
            &mut Rng::new(exec_seed),
        );
        assert!(report.replans.is_empty());
        assert!(
            report.makespan == makespan,
            "makespan drifted: {} vs historical {makespan}",
            report.makespan
        );
        for r in &report.records {
            assert!(
                r.start == start[r.task],
                "task {} start drifted: {} vs historical {}",
                r.task,
                r.start,
                start[r.task]
            );
            assert!(r.runtime == runtimes[r.task]);
            assert_eq!(r.config, plan.schedule.assignment[r.task]);
        }
    }
}

// ---------------------------------------------------------------------------
// 8. Heterogeneous market: the co-optimizer flips instance families (and
//    the purchase option) between the runtime and cost goals. Exact pins:
//    Mode::Separate is the deterministic per-task-best + exact-schedule
//    slice of the co-optimizer, so the chosen market rows are analytic.

/// Market problem: full m5/c5/r5 + spot space, oracle grid, market
/// pricing with the given interruption rate.
fn market_problem(dags: &[Dag], capacity: Capacity, interrupt_rate: f64) -> Problem {
    let space = ConfigSpace::market();
    let profiles: Vec<_> = dags
        .iter()
        .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
        .collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let releases = vec![0.0; dags.len()];
    Problem::new(
        dags,
        &releases,
        capacity,
        space,
        grid,
        CostModel::Market { interrupt_rate },
    )
}

/// Index of a named catalog row x nodes x balanced preset in a space.
fn market_config(space: &ConfigSpace, name: &str, nodes: u32) -> usize {
    let instance = catalog::index_by_name(name).expect("catalog row");
    space
        .configs
        .iter()
        .position(|c| {
            *c == Config {
                instance,
                nodes,
                spark: 1,
            }
        })
        .expect("market space carries every catalog row on the full ladder")
}

#[test]
fn scenario_market_families_flip_between_goals() {
    // Two independent tasks with mild contention (alpha > 0 so the
    // node-count trade-off has a strict optimum): one cpu-bound (tiny
    // working set), one memory-bound (200 GiB working set).
    let mk = |name: &str, mem_gb: f64| Task {
        name: name.to_string(),
        profile: TaskProfile {
            work: 600.0,
            alpha: 0.05,
            beta: 0.0,
            mem_gb,
            spark_affinity: 0.0,
            noise_sigma: 0.0,
        },
    };
    let dag = Dag::new("market", vec![mk("cpu", 4.0), mk("mem", 200.0)], vec![]).unwrap();
    let dags = vec![dag];
    let p = market_problem(&dags, Capacity::micro(), 0.0);

    let optimize = |goal: Goal| {
        Agora::new(AgoraOptions {
            goal,
            mode: Mode::Separate,
            ..Default::default()
        })
        .optimize(&p)
    };

    // Runtime goal: both tasks take the fastest feasible parallelism —
    // 16 x c5.4xlarge ON-DEMAND (c5's faster cores beat m5/r5; the spot
    // twin ties on runtime and loses the deterministic first-minimum
    // tie-break to the on-demand row).
    let rt = optimize(Goal::Runtime);
    let c5_od_16 = market_config(&p.space, "c5.4xlarge", 16);
    assert_eq!(rt.schedule.assignment, vec![c5_od_16; 2]);
    for &c in &rt.schedule.assignment {
        let cfg = p.space.configs[c];
        assert_eq!(cfg.family(), Family::C5);
        assert!(!cfg.is_spot());
    }
    // Exact pins: each task runs 600 * pen(16) / 1.18 seconds and the
    // two 256-vCPU tasks serialize on the 256-vCPU cluster.
    let d_rt = dags[0].tasks[0].profile.runtime(&p.space.configs[c5_od_16]);
    assert!((rt.makespan - (d_rt + d_rt)).abs() < 1e-9, "rt makespan {}", rt.makespan);
    let hourly_rt = p.space.configs[c5_od_16].hourly_cost();
    let want_rt_cost = 2.0 * (hourly_rt * d_rt / 3600.0);
    assert!((rt.cost - want_rt_cost).abs() < 1e-9, "rt cost {}", rt.cost);

    // Cost goal: the cpu-bound task buys the cheapest speed-adjusted
    // vCPUs on the market — c5 SPOT at minimum parallelism — while the
    // memory-bound task flips family to r5 SPOT (2 nodes: enough memory
    // to avoid the spill penalty at the lowest price).
    let cost = optimize(Goal::Cost);
    let c5_spot_1 = market_config(&p.space, "c5.4xlarge:spot", 1);
    let r5_spot_2 = market_config(&p.space, "r5.4xlarge:spot", 2);
    assert_eq!(cost.schedule.assignment[0], c5_spot_1, "cpu task");
    assert_eq!(cost.schedule.assignment[1], r5_spot_2, "mem task");
    assert_eq!(p.space.configs[c5_spot_1].family(), Family::C5);
    assert_eq!(p.space.configs[r5_spot_2].family(), Family::R5);
    assert!(p.space.configs[c5_spot_1].is_spot());
    assert!(p.space.configs[r5_spot_2].is_spot());

    // Exact pins: both fit side by side (48 vCPUs), so the makespan is
    // the cpu task's duration; the cost is the catalog spot prices.
    let d_cpu = dags[0].tasks[0].profile.runtime(&p.space.configs[c5_spot_1]);
    let d_mem = dags[0].tasks[1].profile.runtime(&p.space.configs[r5_spot_2]);
    assert!(d_cpu > d_mem, "cpu {d_cpu} vs mem {d_mem}");
    assert!((cost.makespan - d_cpu).abs() < 1e-9, "cost makespan {}", cost.makespan);
    let want_cost = p.space.configs[c5_spot_1].hourly_cost() * d_cpu / 3600.0
        + p.space.configs[r5_spot_2].hourly_cost() * d_mem / 3600.0;
    assert!((cost.cost - want_cost).abs() < 1e-9, "cost {}", cost.cost);

    // The headline orientation: different families per goal, and the
    // market trade-off is real (cost goal much cheaper, runtime goal
    // much faster).
    assert_ne!(rt.schedule.assignment, cost.schedule.assignment);
    assert!(cost.cost < rt.cost * 0.5);
    assert!(rt.makespan < cost.makespan * 0.5);

    // Bitwise determinism of the market plans.
    let rt2 = optimize(Goal::Runtime);
    let cost2 = optimize(Goal::Cost);
    assert_eq!(rt.schedule.assignment, rt2.schedule.assignment);
    assert_eq!(rt.schedule.start, rt2.schedule.start);
    assert_eq!(cost.schedule.assignment, cost2.schedule.assignment);
    assert_eq!(cost.schedule.start, cost2.schedule.start);
}

// ---------------------------------------------------------------------------
// 9. Spot preemption triggers replanning: a pinned preemption on a spot
//    node blows the plan past the threshold; the replan flips the cone
//    task to a faster family and the realized market cost is exactly
//    the catalog prices times realized occupancy.

#[test]
fn scenario_spot_preemption_triggers_replan_with_exact_pins() {
    // a -> c; b and d independent. Everything planned on 1 x
    // m5.4xlarge:spot; the two-wide cluster fits two such nodes.
    let dag = Dag::new(
        "spot-replan",
        vec![
            exact_task("a", 10.0),
            exact_task("b", 10.0),
            exact_task("c", 10.0),
            exact_task("d", 2.0),
        ],
        vec![(0, 2)],
    )
    .unwrap();
    let dags = vec![dag];
    let p = market_problem(&dags, two_wide(), 0.0);
    let m5_spot_1 = market_config(&p.space, "m5.4xlarge:spot", 1);
    let plan = manual_plan(&p, m5_spot_1, &[0.0, 0.0, 10.0, 10.0]);

    // Task a is preempted once (pinned): loses exactly half the run.
    let divergence = DivergenceSpec {
        spot_tasks: vec![0],
        ..Default::default()
    };
    let stale_policy = ReplanPolicy {
        divergence: divergence.clone(),
        ..ReplanPolicy::off()
    };
    let replan_policy = ReplanPolicy {
        threshold: 0.2,
        max_replans: 1,
        iters: 80,
        divergence,
        ..Default::default()
    };
    let model = CostModel::Market { interrupt_rate: 0.0 };

    let stale = execute_with_policy(&p, &dags, &plan, &model, &mut Rng::new(90), &stale_policy);
    let run = |seed| {
        execute_with_policy(&p, &dags, &plan, &model, &mut Rng::new(seed), &replan_policy)
    };
    let adapted = run(90);
    assert_reports_bit_identical(&adapted, &run(90));

    // Stale world: a 0-15 (10 x 1.5), b 0-10, d 10-12 backfilled,
    // c 15-25 on the stale 1-node spot config.
    assert_eq!(stale.records[0].preemptions, 1);
    assert!((stale.records[0].runtime - 15.0).abs() < 1e-9);
    assert!((stale.makespan - 25.0).abs() < 1e-9, "stale {}", stale.makespan);
    assert!(stale.replans.is_empty());
    let spot_hourly = p.space.configs[m5_spot_1].hourly_cost();
    let stale_cost = spot_hourly * (15.0 + 10.0 + 10.0 + 2.0) / 3600.0;
    assert!((stale.cost - stale_cost).abs() < 1e-9, "stale cost {}", stale.cost);

    // Adapted: a's divergent completion at t=15 fires ((15-10)/20 =
    // 0.25 > 0.2); the cone {c} flips to 2 x c5.4xlarge on-demand (the
    // fastest feasible config on the now-empty cluster) and runs
    // 15 -> 15 + 5/1.18.
    assert_eq!(adapted.replans.len(), 1);
    let e = &adapted.replans[0];
    assert_eq!(e.trigger_task, 0);
    assert!((e.at - 15.0).abs() < 1e-9);
    assert!((e.divergence - 0.25).abs() < 1e-9);
    assert_eq!(e.replanned, 1);
    assert_eq!(e.reassigned, 1);
    assert!((e.stale_makespan - 25.0).abs() < 1e-9);

    let c5_od_2 = market_config(&p.space, "c5.4xlarge", 2);
    assert_eq!(adapted.records[2].config, c5_od_2);
    let d_c = 5.0 / 1.18; // 10 s of work at n_eff 2, c5 speed
    assert!((adapted.records[2].start - 15.0).abs() < 1e-9);
    assert!((adapted.records[2].runtime - d_c).abs() < 1e-9);
    assert!((adapted.makespan - (15.0 + d_c)).abs() < 1e-9, "adapted {}", adapted.makespan);
    assert!((e.planned_makespan - (15.0 + d_c)).abs() < 1e-9);
    assert!(
        adapted.makespan < stale.makespan - 5.0,
        "replanning must strictly improve: {} vs {}",
        adapted.makespan,
        stale.makespan
    );
    // The preempted record itself is immutable history.
    assert_eq!(adapted.records[0].preemptions, 1);
    assert!((adapted.records[0].runtime - 15.0).abs() < 1e-9);
    // Realized market cost: spot occupancy (a, b, d) at the spot price,
    // the reassigned c at the on-demand c5 price.
    let c5_hourly = p.space.configs[c5_od_2].hourly_cost();
    let want_cost =
        spot_hourly * (15.0 + 10.0 + 2.0) / 3600.0 + c5_hourly * d_c / 3600.0;
    assert!((adapted.cost - want_cost).abs() < 1e-9, "adapted cost {}", adapted.cost);
}

// ---------------------------------------------------------------------------
// 10. Seeded spot market batch: bitwise determinism of the seeded
//     preemption process with replanning armed on the market space.

#[test]
fn scenario_seeded_spot_market_batch_is_bitwise_deterministic() {
    let dags = vec![
        arbitrary_dag(&mut Rng::new(801), 10),
        arbitrary_dag(&mut Rng::new(802), 8),
    ];
    let p = market_problem(&dags, Capacity::micro(), 1.0);
    // Cost-goal per-task-best + exact schedule: a deterministic,
    // spot-heavy market plan (planned once; execution must be
    // load-independent, which is what this scenario pins).
    let plan = Agora::new(AgoraOptions {
        goal: Goal::Cost,
        mode: Mode::Separate,
        ..Default::default()
    })
    .optimize(&p);
    let spot_tasks = plan
        .schedule
        .assignment
        .iter()
        .filter(|&&c| p.space.configs[c].is_spot())
        .count();
    assert!(
        spot_tasks > 0,
        "a cost-goal market plan should buy spot capacity"
    );

    let policy = ReplanPolicy {
        threshold: 0.15,
        max_replans: 2,
        iters: 60,
        seed: 806,
        divergence: DivergenceSpec {
            spot_rate: 2.0,
            spot_tasks: vec![0], // at least one guaranteed preemption
            seed: 807,
            ..Default::default()
        },
        ..Default::default()
    };
    let model = CostModel::Market { interrupt_rate: 1.0 };
    let run = |seed| {
        execute_with_policy(&p, &dags, &plan.schedule, &model, &mut Rng::new(seed), &policy)
    };
    let a = run(808);
    assert_reports_bit_identical(&a, &run(808));

    assert!(a.records[0].preemptions >= 1, "pinned preemption realized");
    for r in &a.records {
        assert!(r.preemptions <= policy.divergence.spot_max);
        assert!(r.runtime > 0.0 && r.runtime.is_finite());
        assert!(p.space.configs[r.config].vcpus() <= p.capacity.vcpus + 1e-9);
    }
    assert!(a.replans.len() <= policy.max_replans);
    let longest = a.records.iter().map(|r| r.runtime).fold(0.0, f64::max);
    assert!(a.makespan >= longest - 1e-6);
    assert!(a.cost > 0.0 && a.cost.is_finite());
}

// ---------------------------------------------------------------------------
// 11. Deadline-at-risk spot migration: a pinned preemption pushes a
//     hard-SLA DAG past its deadline without crossing the divergence
//     threshold. The SLA-blind policy therefore never replans and
//     misses; the SLA-aware policy (same policy + spot surcharge) fires
//     the deadline-risk trigger, flips exactly the at-risk cone to the
//     cheapest on-demand row (c5.4xlarge), and meets the deadline.

#[test]
fn scenario_deadline_at_risk_cone_flips_spot_to_on_demand() {
    // Chain a -> c on a one-node cluster (16 vCPUs / 64 GiB): the only
    // feasible rows are 1 x m5.4xlarge and 1 x c5.4xlarge, on-demand
    // and spot. Both tasks planned on the m5 spot row: makespan 20.
    let dag = Dag::new(
        "sla-flip",
        vec![exact_task("a", 10.0), exact_task("c", 10.0)],
        vec![(0, 1)],
    )
    .unwrap();
    let dags = vec![dag];
    let p = market_problem(&dags, Capacity::new(16.0, 64.0), 0.0)
        .with_slas(vec![Sla::hard(24.0)]);
    let m5_spot_1 = market_config(&p.space, "m5.4xlarge:spot", 1);
    let plan = manual_plan(&p, m5_spot_1, &[0.0, 10.0]);

    // Task a is preempted once (pinned): runs 0-15 (10 x 1.5). Its
    // divergence is (15 - 10) / 20 = 0.25, below the 0.5 threshold, so
    // only the deadline-risk rule can trigger a replan — and the
    // projected completion 15 + 10 = 25 misses the hard deadline 24.
    let divergence = DivergenceSpec {
        spot_tasks: vec![0],
        ..Default::default()
    };
    let blind_policy = ReplanPolicy {
        threshold: 0.5,
        max_replans: 1,
        iters: 80,
        goal: Goal::Cost,
        divergence,
        ..Default::default()
    };
    let aware_policy = ReplanPolicy {
        sla_spot_penalty: 10.0,
        ..blind_policy.clone()
    };
    let model = CostModel::Market { interrupt_rate: 0.0 };

    let blind =
        execute_with_policy(&p, &dags, &plan, &model, &mut Rng::new(1100), &blind_policy);
    let run = |seed| {
        execute_with_policy(&p, &dags, &plan, &model, &mut Rng::new(seed), &aware_policy)
    };
    let aware = run(1100);
    assert_reports_bit_identical(&aware, &run(1100));

    // SLA-blind: no replan fires (divergence under threshold), so the
    // DAG finishes at 25 on the stale spot plan — a hard miss.
    assert!(blind.replans.is_empty());
    assert_eq!(blind.records[0].preemptions, 1);
    assert!((blind.records[0].runtime - 15.0).abs() < 1e-9);
    assert!((blind.makespan - 25.0).abs() < 1e-9, "blind {}", blind.makespan);
    assert!(blind.dag_completion[0] > 24.0, "blind must miss the deadline");
    let spot_hourly = p.space.configs[m5_spot_1].hourly_cost();
    let blind_cost = spot_hourly * (15.0 + 10.0) / 3600.0;
    assert!((blind.cost - blind_cost).abs() < 1e-9, "blind cost {}", blind.cost);

    // SLA-aware: a's completion at t=15 fires the deadline-risk trigger
    // despite div 0.25 <= 0.5; the cone {c} flips to the cheapest
    // on-demand row — c5.4xlarge, one node — and the DAG meets 24.
    assert_eq!(aware.replans.len(), 1);
    let e = &aware.replans[0];
    assert_eq!(e.trigger_task, 0);
    assert!((e.at - 15.0).abs() < 1e-9);
    assert!((e.divergence - 0.25).abs() < 1e-9);
    assert_eq!(e.replanned, 1);
    assert_eq!(e.reassigned, 1);
    assert!((e.stale_makespan - 25.0).abs() < 1e-9);

    let cfg = p.space.configs[aware.records[1].config];
    assert!(!cfg.is_spot(), "at-risk cone must leave spot capacity");
    assert_eq!(cfg.family(), Family::C5);
    assert_eq!(cfg.nodes, 1);
    let d_c = 10.0 / 1.18; // 10 s of work at 1 node, c5 speed
    assert!((aware.records[1].start - 15.0).abs() < 1e-9);
    assert!((aware.records[1].runtime - d_c).abs() < 1e-9);
    assert!((aware.makespan - (15.0 + d_c)).abs() < 1e-9, "aware {}", aware.makespan);
    assert!((e.planned_makespan - (15.0 + d_c)).abs() < 1e-9);
    assert!(
        aware.dag_completion[0] <= 24.0 + 1e-9,
        "aware must meet the hard deadline: {}",
        aware.dag_completion[0]
    );
    // The preempted record itself is immutable history.
    assert_eq!(aware.records[0].preemptions, 1);
    assert!((aware.records[0].runtime - 15.0).abs() < 1e-9);
    // Realized market cost: a at the spot price for its inflated run,
    // the migrated c at the on-demand c5 price.
    let c5_hourly = cfg.hourly_cost();
    let want_cost = spot_hourly * 15.0 / 3600.0 + c5_hourly * d_c / 3600.0;
    assert!((aware.cost - want_cost).abs() < 1e-9, "aware cost {}", aware.cost);
}
