//! Ablation (DESIGN.md §4): pricing-model sensitivity — the extension
//! the paper sketches in §4.2 ("spot instances in AWS have a dynamic
//! pricing model ... AGORA can be easily modified to include these
//! details by defining the C_m variable more accurately").
//!
//! We co-optimize DAG1+DAG2 at the balanced goal under three cost
//! models and report how the chosen configurations shift:
//!   * on-demand (Eq. 6 baseline),
//!   * spot (30% of on-demand; interruptions arrive per **node-hour**,
//!     so the expected re-run overhead grows with a task's exposed
//!     node-seconds — gang size x duration — not wall time alone),
//!   * per-second billing with a 60 s minimum (billing granularity).
//!
//! Expected shape: under per-node-hour interruptions, scaling out does
//! not shed spot risk (halving the runtime doubles the exposed nodes;
//! USL contention makes big gangs strictly worse), so spot pricing
//! pushes the optimizer toward SMALLER gangs than on-demand; per-second
//! minimums are irrelevant at these task lengths (all >> 60 s).

#[path = "common/mod.rs"]
mod common;

use agora::bench;
use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::workloads::{dag1, dag2};
use agora::solver::{Agora, AgoraOptions, Goal, Problem};
use agora::util::{fmt_cost, fmt_duration, Rng};
use agora::{LearnedPredictor, Predictor};

fn problem_with(cost_model: CostModel, rng: &mut Rng) -> Problem {
    let dags = vec![dag1(), dag2()];
    let space = ConfigSpace::standard();
    let logs = common::logs_for(&dags, rng);
    let grid = LearnedPredictor::fit(&logs).predict(&space);
    Problem::new(
        &dags,
        &[0.0, 0.0],
        Capacity::micro(),
        space,
        grid,
        cost_model,
    )
}

fn main() {
    bench::header(
        "Ablation: cost models",
        "co-optimization under on-demand / spot / per-second pricing (balanced goal)",
    );

    let models: Vec<(&str, CostModel)> = vec![
        ("on-demand", CostModel::OnDemand),
        (
            "spot (30%, 0.5 interrupts/h)",
            CostModel::Spot {
                discount: 0.30,
                interrupt_rate: 0.5,
            },
        ),
        (
            "per-second (60s min)",
            CostModel::PerSecond {
                min_billable_secs: 60.0,
            },
        ),
    ];

    let mut rows = Vec::new();
    let mut mean_neff = Vec::new();
    for (name, model) in &models {
        let mut rng = Rng::new(common::SEED);
        let p = problem_with(model.clone(), &mut rng);
        let plan = Agora::new(AgoraOptions {
            goal: Goal::Balanced,
            seed: common::SEED,
            ..Default::default()
        })
        .optimize(&p);

        let avg_neff: f64 = plan
            .schedule
            .assignment
            .iter()
            .map(|&c| p.space.configs[c].n_eff())
            .sum::<f64>()
            / p.len() as f64;
        mean_neff.push((*name, avg_neff));
        rows.push(vec![
            name.to_string(),
            fmt_duration(plan.makespan),
            fmt_cost(plan.cost),
            format!("{avg_neff:.1}"),
            format!("{:?}", plan.overhead),
        ]);
    }
    bench::table(
        &["pricing model", "makespan", "cost", "mean n_eff", "overhead"],
        &rows,
    );

    let od = mean_neff.iter().find(|(n, _)| *n == "on-demand").unwrap().1;
    let spot = mean_neff
        .iter()
        .find(|(n, _)| n.starts_with("spot"))
        .unwrap()
        .1;
    println!(
        "\nspot pricing shifts mean parallelism {od:.1} -> {spot:.1} n_eff \
         ({}): interruptions arrive per node-hour, so node-seconds — not \
         wall time — are the exposed surface and big gangs carry more \
         expected re-run work",
        if spot <= od { "smaller gangs, as expected" } else { "not visible at this seed" }
    );
    println!(
        "per-second minimum billing is inert at these task durations (all >> 60 s) — \
         the knob matters for sub-minute functions, not Spark stages."
    );
}
