//! Fig. 13 (extension) — deadline/SLA-constrained scheduling: SLA-aware
//! admission + deadline-cost planning vs an SLA-blind cost planner on
//! the trace workload, plus AGORA's simulated annealing vs a
//! CEDCES-style evolutionary scheduler under an equal evaluation
//! budget.
//!
//! Reproduction target: admission control converts hard-deadline misses
//! into explicit rejections/deferrals — the SLA-aware column never
//! realizes **more** hard misses than the SLA-blind one — and the
//! co-optimizer's annealer matches or beats the evolutionary baseline
//! on penalized cost at the same number of schedule evaluations.
//!
//! `cargo bench --bench fig13_deadlines -- --smoke` runs the cheap
//! deterministic slice and asserts the miss ordering — the CI pin that
//! keeps the SLA pipeline end-to-end alive.

#[path = "common/mod.rs"]
mod common;

use agora::baselines::{EvolutionaryScheduler, Scheduler};
use agora::bench;
use agora::cluster::ConfigSpace;
use agora::coordinator::{BatchRunner, MacroReport, SlaPolicy, SlaStats, Strategy};
use agora::dag::workloads::{dag1, dag2};
use agora::solver::{Agora, AgoraOptions, AnnealParams, Goal, Mode, Sla};
use agora::trace::{generate, TraceParams};
use agora::util::{fmt_cost, fmt_duration, Rng};

/// Deadline slack as a multiple of each DAG's critical-path lower bound.
const DEADLINE_FRAC: f64 = 2.0;
/// Soft-SLA penalty rate for the GA-vs-SA comparison.
const PENALTY_PER_SEC: f64 = 0.01;

fn run_trace(
    jobs: &[agora::trace::TracedJob],
    params: &TraceParams,
    strategy: Strategy,
    sla: SlaPolicy,
) -> MacroReport {
    let mut runner = BatchRunner::new(
        params.batch_capacity(),
        ConfigSpace::standard(),
        strategy,
        common::SEED,
    )
    .with_sla(sla);
    runner.run(jobs).expect("macro run")
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench::header(
        "Figure 13 (extension)",
        "deadline/SLA-constrained scheduling: admission control + deadline-cost planning",
    );
    println!(
        "mode: {}  (deadlines at {DEADLINE_FRAC}x each DAG's critical-path bound)\n",
        if smoke { "smoke (--smoke)" } else { "full sweep" }
    );

    // -- Part 1: SLA-aware vs SLA-blind on the trace workload. --------
    let params = TraceParams::tiny();
    let jobs = generate(&params, &mut Rng::new(common::SEED));

    let aware_policy = SlaPolicy {
        deadline_frac: DEADLINE_FRAC,
        penalty_per_sec: 0.0,
        hard: true,
        enforce: true,
    };
    let blind_policy = SlaPolicy {
        enforce: false,
        ..aware_policy.clone()
    };
    // Smoke keeps the deterministic per-task-best slice; the full sweep
    // runs the SA co-optimizer.
    let (aware_strategy, blind_strategy) = if smoke {
        (
            Strategy::AgoraMode(Goal::DeadlineCost, Mode::Separate),
            Strategy::AgoraMode(Goal::Cost, Mode::Separate),
        )
    } else {
        (
            Strategy::Agora(Goal::DeadlineCost),
            Strategy::Agora(Goal::Cost),
        )
    };
    let aware = run_trace(&jobs, &params, aware_strategy, aware_policy);
    let blind = run_trace(&jobs, &params, blind_strategy, blind_policy);

    let mut rows = Vec::new();
    for (label, rep) in [("sla-aware", &aware), ("sla-blind", &blind)] {
        let s = SlaStats::of(rep);
        let r = s.row();
        rows.push(vec![
            label.to_string(),
            r[1].clone(),
            r[2].clone(),
            r[3].clone(),
            r[4].clone(),
            r[5].clone(),
        ]);
    }
    bench::table(
        &["mode", "met", "missed", "rejected", "penalty", "cost"],
        &rows,
    );

    // The headline direction — and the CI pin: admission control turns
    // would-be hard misses into explicit rejections/deferrals, so the
    // aware run can never realize more misses than the blind one.
    assert!(
        aware.sla_missed <= blind.sla_missed,
        "SLA-aware admission realized more hard misses ({}) than the \
         SLA-blind baseline ({})",
        aware.sla_missed,
        blind.sla_missed
    );
    println!(
        "\nhard misses: aware {} <= blind {} — admission control holds the line",
        aware.sla_missed, blind.sla_missed
    );

    // -- Part 2: SA vs CEDCES-style GA at an equal evaluation budget. --
    let evals = if smoke { 120 } else { 400 };
    let (p, _dags) = common::learned_problem(vec![dag1(), dag2()], &mut Rng::new(common::SEED));
    let slas: Vec<Sla> = p
        .dag_lower_bounds()
        .iter()
        .map(|&lb| Sla::soft(DEADLINE_FRAC * lb, PENALTY_PER_SEC))
        .collect();
    let p = p.with_slas(slas);

    // The GA runs first and reports the schedule decodes it *actually*
    // spent — fitness evaluations plus repair probes, the historically
    // uncounted part of its budget. The SA side then gets exactly that
    // many iterations, so the duel is equal-cost in the shared budget
    // currency (computed schedule evaluations).
    let ga = EvolutionaryScheduler::with_budget(evals);
    let (ga_schedule, ga_decodes) = ga.schedule_counted(&p).expect("GA schedule");
    ga_schedule.validate(&p).expect("GA schedule feasible");

    let sa = Agora::new(AgoraOptions {
        goal: Goal::DeadlineCost,
        mode: Mode::CoOptimize,
        params: AnnealParams {
            max_iters: ga_decodes,
            ..Default::default()
        },
        seed: common::SEED,
        ..Default::default()
    })
    .optimize(&p);
    sa.schedule.validate(&p).expect("SA schedule feasible");
    let sa_evals = sa
        .anneal
        .as_ref()
        .map(|a| a.stats.evaluations)
        .unwrap_or(0);

    let penalized = |makespan: f64, cost: f64| {
        cost + p
            .slas
            .iter()
            .map(|s| s.penalty(makespan))
            .sum::<f64>()
    };
    let sa_obj = penalized(sa.makespan, sa.cost);
    let ga_obj = penalized(ga_schedule.makespan(&p), ga_schedule.cost(&p));
    println!(
        "\n-- SA vs evolutionary at an equal budget: the GA spent {ga_decodes} \
         schedule decodes (nominal {evals}), the SA cap matches it --"
    );
    bench::table(
        &["optimizer", "evaluations", "makespan", "cost", "penalized cost"],
        &[
            vec![
                "agora-sa".to_string(),
                sa_evals.to_string(),
                fmt_duration(sa.makespan),
                fmt_cost(sa.cost),
                fmt_cost(sa_obj),
            ],
            vec![
                ga.name().to_string(),
                ga_decodes.to_string(),
                fmt_duration(ga_schedule.makespan(&p)),
                fmt_cost(ga_schedule.cost(&p)),
                fmt_cost(ga_obj),
            ],
        ],
    );
    println!(
        "\nreading: rust/tests/deadlines.rs pins the SA-vs-GA differential on a \
         hand-checkable problem; here both searches face the learned predictor."
    );
}
