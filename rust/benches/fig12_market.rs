//! Fig. 12 (extension) — the heterogeneous instance market: on-demand
//! m5-only plans vs mixed-market plans (m5/c5/r5 x on-demand/spot) on
//! the trace workloads, with the spot interruption process realized by
//! the executor.
//!
//! Reproduction target (paper §2 + §5): the market's extra degrees of
//! freedom are where the large cost headroom lives — the cost goal
//! shifts work onto discounted (spot/c5/r5) capacity, the runtime goal
//! onto faster compute-optimized cores, and realized spot costs include
//! the preemption re-runs the planner's closed form prices in
//! expectation.
//!
//! `cargo bench --bench fig12_market -- --smoke` runs the cheap
//! deterministic slice (per-task-best + exact schedule, one goal) — the
//! CI pin that keeps the market pipeline end-to-end alive.

#[path = "common/mod.rs"]
mod common;

use agora::bench;
use agora::cluster::ConfigSpace;
use agora::coordinator::{BatchRunner, MacroReport, Strategy};
use agora::solver::{Goal, Mode};
use agora::trace::{generate, TraceParams};
use agora::util::{fmt_cost, fmt_duration, Rng};
use agora::CostModel;
use agora::sim::{DivergenceSpec, ReplanPolicy};

/// Expected spot interruptions per node-hour in the market columns.
const SPOT_RATE: f64 = 1.0;

fn run_market(
    jobs: &[agora::trace::TracedJob],
    params: &TraceParams,
    strategy: Strategy,
    market: bool,
) -> MacroReport {
    let (space, model) = if market {
        (
            ConfigSpace::market(),
            CostModel::Market {
                interrupt_rate: SPOT_RATE,
            },
        )
    } else {
        (ConfigSpace::standard(), CostModel::OnDemand)
    };
    let replan = ReplanPolicy {
        divergence: DivergenceSpec {
            spot_rate: SPOT_RATE,
            seed: common::SEED ^ 0x51,
            ..Default::default()
        },
        ..ReplanPolicy::off()
    };
    let mut runner = BatchRunner::new(params.batch_capacity(), space, strategy, common::SEED)
        .with_cost_model(model)
        .with_replan(replan);
    runner.run(jobs).expect("macro run")
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench::header(
        "Figure 12 (extension)",
        "instance market: on-demand m5-only vs mixed m5/c5/r5 + spot plans",
    );
    println!(
        "mode: {}  (spot rate {SPOT_RATE}/node-hour; realized preemptions re-run lost work)\n",
        if smoke { "smoke (--smoke)" } else { "full sweep" }
    );

    let params = TraceParams::tiny();
    let jobs = generate(&params, &mut Rng::new(common::SEED));

    // Smoke: the deterministic per-task-best slice only; full: the SA
    // co-optimizer per goal.
    let goals: &[Goal] = if smoke {
        &[Goal::Cost]
    } else {
        &[Goal::Cost, Goal::Runtime]
    };

    let mut rows = Vec::new();
    for &goal in goals {
        let strategy = if smoke {
            Strategy::AgoraMode(goal, Mode::Separate)
        } else {
            Strategy::Agora(goal)
        };
        let od = run_market(&jobs, &params, strategy.clone(), false);
        let mkt = run_market(&jobs, &params, strategy.clone(), true);
        for (label, rep) in [("m5 on-demand", &od), ("mixed market", &mkt)] {
            rows.push(vec![
                format!("{} / {}", goal.name(), label),
                fmt_cost(rep.total_cost),
                fmt_duration(rep.total_completion),
                format!("{}", rep.preemptions),
                format!("{}", rep.rounds),
            ]);
        }

        // The headline direction: under the cost goal the market must
        // be cheaper — its on-demand-only plan is still in the search
        // space, and spot/c5/r5 rows undercut it per unit of work.
        if goal == Goal::Cost {
            let ratio = mkt.total_cost / od.total_cost;
            println!(
                "cost goal: market total cost is {:.0}% of m5-on-demand-only{}",
                ratio * 100.0,
                if ratio < 1.0 {
                    " — the market headroom is real"
                } else {
                    " (degraded at this seed: search missed the market rows)"
                }
            );
            assert!(
                ratio < 1.05,
                "mixed-market cost-goal plan should never be materially \
                 costlier than the m5-only plan (ratio {ratio:.3})"
            );
        }
        if goal == Goal::Cost && mkt.preemptions == 0 {
            println!("note: no spot preemptions realized at this seed/rate");
        }
    }
    bench::table(
        &["goal / space", "total cost", "total completion", "preempts", "rounds"],
        &rows,
    );

    if !smoke {
        println!(
            "\nreading: the cost column is realized (preemption re-runs included); \
             the planner prices them via the capped-Poisson closed form — \
             rust/tests/market.rs pins the two against each other."
        );
    }
}
