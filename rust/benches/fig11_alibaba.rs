//! Fig. 11 — macro-benchmark on the (synthetic) Alibaba-like production
//! trace: normalized cost + total DAG completion time, and the CDF of
//! per-DAG completion improvements.
//!
//! Paper headline: cost -65%, total completion -57%, 87% of DAGs
//! improved, 45% improved by ~100%. Our trace is a statistically shaped
//! substitute (see rust/src/trace/), so shape — large double-digit
//! reductions, most DAGs improved — is the reproduction target.
//!
//! A tail section duels Ernest+DAGPS against Ernest+CP per traced DAG,
//! isolating the troublesome-subgraph list order at a fixed assignment.

#[path = "common/mod.rs"]
mod common;

use agora::baselines::{CriticalPathScheduler, DagpsScheduler, ErnestGoal, Scheduler};
use agora::bench;
use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::coordinator::{
    improvement_cdf, Admission, AdmissionStats, BatchRunner, MacroSummary, Strategy,
};
use agora::predictor::OraclePredictor;
use agora::solver::{Goal, Problem};
use agora::trace::{arrival_rate_per_hour, generate, TraceParams, TracedJob};
use agora::util::{fmt_cost, fmt_duration, Rng};
use agora::Predictor;

fn main() {
    bench::header(
        "Figure 11",
        "Alibaba-like macro trace: AGORA vs default Airflow (multi-DAG, triggered rounds)",
    );
    let jobs = if std::env::var_os("AGORA_BENCH_FULL").is_some() { 120 } else { 48 };
    // A deliberately contended slice of the cluster: the paper's macro
    // gains are dominated by queueing (87% of DAGs improve because
    // efficient packing drains the backlog), so the batch share must be
    // small relative to the offered load, like the production trace.
    let params = TraceParams::contended(jobs);
    let mut rng = Rng::new(common::SEED);
    let trace = generate(&params, &mut rng);
    let tasks: usize = trace.iter().map(|j| j.dag.len()).sum();
    println!(
        "trace: {} DAGs / {} tasks over {} ({:.0} DAGs/h); batch capacity {:.0} cores, {:.0} GiB",
        trace.len(),
        tasks,
        fmt_duration(params.window),
        arrival_rate_per_hour(&trace),
        params.batch_capacity().vcpus,
        params.batch_capacity().memory_gb
    );
    println!("triggers: 15 min OR queue demand > 3x cores; seed = {}\n", common::SEED);

    let t0 = std::time::Instant::now();
    let mut base_runner = BatchRunner::new(
        params.batch_capacity(),
        ConfigSpace::standard(),
        Strategy::Airflow,
        common::SEED,
    );
    let base = base_runner.run(&trace).expect("airflow macro run");
    println!(
        "airflow: {} rounds, total cost {}, total completion {} ({:?})",
        base.rounds,
        fmt_cost(base.total_cost),
        fmt_duration(base.total_completion),
        t0.elapsed()
    );

    let t1 = std::time::Instant::now();
    let mut agora_runner = BatchRunner::new(
        params.batch_capacity(),
        ConfigSpace::standard(),
        Strategy::Agora(Goal::Balanced),
        common::SEED,
    );
    let run = agora_runner.run(&trace).expect("agora macro run");
    println!(
        "agora  : {} rounds, total cost {}, total completion {} ({:?}; optimizer {:?})",
        run.rounds,
        fmt_cost(run.total_cost),
        fmt_duration(run.total_completion),
        t1.elapsed(),
        run.optimizer_overhead
    );

    let s = MacroSummary::against(&base, &run);
    println!("\n-- Fig. 11 left: normalized totals (airflow = 1.0) --");
    bench::table(
        &["metric", "normalized", "reduction", "paper"],
        &[
            vec![
                "total cost".into(),
                format!("{:.2}", s.normalized_cost),
                format!("{:.0}%", (1.0 - s.normalized_cost) * 100.0),
                "65%".into(),
            ],
            vec![
                "total completion".into(),
                format!("{:.2}", s.normalized_completion),
                format!("{:.0}%", (1.0 - s.normalized_completion) * 100.0),
                "57%".into(),
            ],
        ],
    );

    println!("\n-- Fig. 11 right: CDF of per-DAG completion improvement --");
    let cdf = improvement_cdf(&base, &run);
    let points: Vec<(f64, Vec<f64>)> = (0..=10)
        .map(|i| {
            let q = i as f64 / 10.0;
            let idx = ((cdf.len() - 1) as f64 * q) as usize;
            (q, vec![cdf[idx] * 100.0])
        })
        .collect();
    bench::series("CDF (x = fraction of DAGs, y = improvement %)", "fraction", &["improvement %"], &points);
    println!(
        "\nDAGs improved: {:.0}% (paper 87%); improved >= 95%: {:.0}% (paper ~45%)",
        s.improved_fraction * 100.0,
        s.near_total_fraction * 100.0
    );

    dagps_head_to_head(&trace, params.batch_capacity());

    // Continuous vs round-barrier admission at equal cost budget: the
    // same strategy + seed draws identical runtimes in both modes, so
    // these columns isolate the head-of-line-blocking effect of the
    // bulk-synchronous round barrier. Measured on the admission-stress
    // slice (multi-slot capacity + compressed arrivals), where triggered
    // rounds genuinely overlap; on a one-task-at-a-time slice the two
    // modes coincide by construction (a serial chain has no gaps).
    let stress = TraceParams::admission_stress(jobs);
    let mut stress_rng = Rng::new(common::SEED);
    let stress_trace = generate(&stress, &mut stress_rng);
    println!(
        "\n-- admission: round-barrier vs continuous (airflow configs, equal cost; {} DAGs over {}, {:.0} cores) --",
        stress_trace.len(),
        fmt_duration(stress.window),
        stress.batch_capacity().vcpus
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for admission in [Admission::Rounds, Admission::Continuous] {
        let mut runner = BatchRunner::new(
            stress.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            common::SEED,
        )
        .with_admission(admission);
        let report = runner.run(&stress_trace).expect("admission macro run");
        rows.push(AdmissionStats::of(&report).row());
    }
    bench::table(
        &["mode", "mean compl", "p95 compl", "queue delay", "util", "cost"],
        &rows,
    );
}

/// Per-problem Ernest+DAGPS vs Ernest+CP duel on the traced DAGs.
///
/// Each job becomes its own single-DAG problem on the batch capacity
/// (oracle runtimes, Balanced Ernest config pick), so the delta isolates
/// the list-scheduling order: same assignment, same capacity, only the
/// troublesome-subgraph prioritization differs. Skewed fan-outs reward
/// front-loading the troublesome subgraphs; serial chains tie.
fn dagps_head_to_head(trace: &[TracedJob], cap: Capacity) {
    let sample = trace.len().min(12);
    println!("\n-- ernest+dagps vs ernest+cp, per-problem makespans ({sample} traced DAGs) --");
    let mut rows = Vec::new();
    let mut wins = 0usize;
    let mut ties = 0usize;
    for (i, job) in trace.iter().take(sample).enumerate() {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = job.dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &[job.dag.clone()],
            &[0.0],
            cap,
            space,
            grid,
            CostModel::OnDemand,
        );
        let cp = CriticalPathScheduler::with_ernest(ErnestGoal(Goal::Balanced))
            .schedule(&p)
            .expect("ernest+cp");
        let dagps = DagpsScheduler::with_ernest(ErnestGoal(Goal::Balanced))
            .schedule(&p)
            .expect("ernest+dagps");
        let (m_cp, m_dagps) = (cp.makespan(&p), dagps.makespan(&p));
        if m_dagps < m_cp - 1e-9 {
            wins += 1;
        } else if (m_dagps - m_cp).abs() <= 1e-9 {
            ties += 1;
        }
        rows.push(vec![
            format!("dag {i} ({} tasks)", job.dag.len()),
            fmt_duration(m_cp),
            fmt_duration(m_dagps),
            bench::pct(m_cp, m_dagps),
        ]);
    }
    bench::table(&["problem", "ernest+cp", "ernest+dagps", "delta"], &rows);
    println!("dagps better on {wins}/{sample}, tied on {ties}");
}
