//! Shared setup for the paper-reproduction bench targets.
//!
//! Every bench regenerates one table/figure of the paper's evaluation.
//! The substrate is the simulated cluster, so absolute numbers differ
//! from the authors' AWS testbed; the *shape* (who wins, rough factors,
//! crossovers) is the reproduction target. Seeds are fixed and printed.

#![allow(dead_code)]

use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::Dag;
use agora::predictor::{bootstrap_history, default_profiling_configs, EventLog};
use agora::solver::{Agora, AgoraOptions, Goal, Mode, Problem, Schedule};
use agora::util::Rng;
use agora::{LearnedPredictor, Predictor};

pub const SEED: u64 = 2022;

/// Event logs for a set of DAGs (Ernest-style profiling bootstrap).
pub fn logs_for(dags: &[Dag], rng: &mut Rng) -> Vec<EventLog> {
    dags.iter()
        .flat_map(|d| {
            d.tasks
                .iter()
                .map(|t| bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), rng))
                .collect::<Vec<_>>()
        })
        .collect()
}

/// Standard micro-benchmark problem: learned predictor over the full
/// config space on the 256-vCPU cluster.
pub fn learned_problem(dags: Vec<Dag>, rng: &mut Rng) -> (Problem, Vec<Dag>) {
    let space = ConfigSpace::standard();
    let logs = logs_for(&dags, rng);
    let grid = LearnedPredictor::fit(&logs).predict(&space);
    let releases = vec![0.0; dags.len()];
    let p = Problem::new(
        &dags,
        &releases,
        Capacity::micro(),
        space,
        grid,
        CostModel::OnDemand,
    );
    (p, dags)
}

/// Execute a schedule with a fixed noise seed (same noise for every
/// policy so comparisons are apples-to-apples).
pub fn realize(p: &Problem, dags: &[Dag], s: &Schedule) -> (f64, f64) {
    let mut rng = Rng::new(0xE0E0);
    let rep = agora::sim::execute(p, dags, s, &CostModel::OnDemand, &mut rng);
    (rep.makespan, rep.cost)
}

/// AGORA plan for a goal. The cost goal carries the paper's observable
/// framing ("lowest cost with comparable runtime against default
/// Airflow"): a makespan budget of 3x the baseline keeps the search in
/// the regime the paper reports.
pub fn agora_plan(p: &Problem, goal: Goal, base_makespan: f64) -> agora::solver::Plan {
    let (makespan_budget, cost_budget) = match goal {
        Goal::Cost => (3.0 * base_makespan, f64::INFINITY),
        _ => (f64::INFINITY, f64::INFINITY),
    };
    Agora::new(AgoraOptions {
        goal,
        mode: Mode::CoOptimize,
        makespan_budget,
        cost_budget,
        seed: SEED,
        ..Default::default()
    })
    .optimize(p)
}

/// [`agora_plan`] with a short fast-parameter search — the `--smoke`
/// variant for CI bench gates (same pipeline, reduced budget).
pub fn agora_plan_quick(p: &Problem, goal: Goal, base_makespan: f64) -> agora::solver::Plan {
    let (makespan_budget, cost_budget) = match goal {
        Goal::Cost => (3.0 * base_makespan, f64::INFINITY),
        _ => (f64::INFINITY, f64::INFINITY),
    };
    Agora::new(AgoraOptions {
        goal,
        mode: Mode::CoOptimize,
        makespan_budget,
        cost_budget,
        seed: SEED,
        params: agora::solver::AnnealParams {
            max_iters: 150,
            ..agora::solver::AnnealParams::fast()
        },
        ..Default::default()
    })
    .optimize(p)
}
