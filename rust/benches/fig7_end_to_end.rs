//! Fig. 7 — end-to-end runtime and cost of DAG1/DAG2 under default
//! Airflow, AGORA, Ernest+CP, Ernest+MILP and Stratus, for the three
//! optimization goals (balanced / runtime / cost).
//!
//! Every policy's plan is executed on the simulated cluster with the
//! SAME run-noise seed, and realized (runtime, cost) points are printed
//! per goal — the scatter of the paper's Fig. 7 as a table. Lower-left
//! dominates.

#[path = "common/mod.rs"]
mod common;

use agora::baselines::{
    AirflowScheduler, CriticalPathScheduler, ErnestGoal, MilpScheduler, Scheduler,
    StratusScheduler,
};
use agora::bench;
use agora::dag::workloads::{dag1, dag2};
use agora::solver::Goal;
use agora::util::{fmt_cost, fmt_duration, Rng};

fn main() {
    bench::header(
        "Figure 7",
        "end-to-end runtime & cost: Airflow / AGORA / Ernest+CP / Ernest+MILP / Stratus",
    );
    println!("seed = {}; all plans executed with identical run noise\n", common::SEED);

    for (dag_name, dag_fn) in [("DAG1", dag1 as fn() -> agora::Dag), ("DAG2", dag2)] {
        let mut rng = Rng::new(common::SEED);
        let (p, dags) = common::learned_problem(vec![dag_fn()], &mut rng);

        // Baseline anchor: default Airflow.
        let airflow = AirflowScheduler::default().schedule(&p).expect("airflow");
        let (air_m, air_c) = common::realize(&p, &dags, &airflow);

        for goal in [Goal::Balanced, Goal::Runtime, Goal::Cost] {
            println!("\n-- {dag_name}, goal = {} --", goal.name());
            let mut rows = Vec::new();
            let mut push = |name: &str, m: f64, c: f64| {
                rows.push(vec![
                    name.to_string(),
                    fmt_duration(m),
                    fmt_cost(c),
                    bench::pct(air_m, m),
                    bench::pct(air_c, c),
                ]);
            };
            push("airflow", air_m, air_c);

            let plan = common::agora_plan(&p, goal, air_m);
            let (m, c) = common::realize(&p, &dags, &plan.schedule);
            push("AGORA", m, c);

            let cp = CriticalPathScheduler::with_ernest(ErnestGoal(goal))
                .schedule(&p)
                .expect("ernest+cp");
            let (m, c) = common::realize(&p, &dags, &cp);
            push("ernest+cp", m, c);

            let milp = MilpScheduler::with_ernest(ErnestGoal(goal))
                .schedule(&p)
                .expect("ernest+milp");
            let (m, c) = common::realize(&p, &dags, &milp);
            push("ernest+milp", m, c);

            if goal == Goal::Cost {
                // Stratus only optimizes cost (paper: implemented
                // "specially for cost").
                let stratus = StratusScheduler::default().schedule(&p).expect("stratus");
                let (m, c) = common::realize(&p, &dags, &stratus);
                push("stratus", m, c);
            }

            bench::table(
                &["policy", "runtime", "cost", "d-runtime", "d-cost"],
                &rows,
            );
        }
    }

    println!(
        "\npaper shape targets: balanced -> AGORA better on BOTH axes \
         (runtime -15..-24%, cost -35..-50%); runtime goal -> -36..-45% runtime \
         at higher cost; cost goal -> lowest cost (-71..-78%) at comparable \
         runtime; Stratus fast but pricier than AGORA; Ernest+CP/MILP can be \
         worse than unoptimized Airflow."
    );
}
