//! Fig. 7 — end-to-end runtime and cost of DAG1/DAG2 under default
//! Airflow, AGORA, Ernest+CP, Ernest+MILP, Ernest+DAGPS and Stratus,
//! for the three optimization goals (balanced / runtime / cost).
//!
//! Every policy's plan is executed on the simulated cluster with the
//! SAME run-noise seed, and realized (runtime, cost) points are printed
//! per goal — the scatter of the paper's Fig. 7 as a table. Lower-left
//! dominates.
//!
//! The tail section duels the troublesome-seeded annealing portfolio
//! against the unseeded one on a wide-fan-out `large_scale_dag` at equal
//! charged budget. At a zero-iteration budget the comparison is
//! structural (the seeded portfolio's winner is the better of the two
//! start points, the unseeded one has only the default start) and is
//! asserted; the deeper equal-budget rows are informational.
//!
//! `cargo bench --bench fig7_end_to_end -- --smoke` runs DAG1 only with
//! a short AGORA search — the CI pin that keeps the DAGPS baseline
//! column and the seeding duel alive.

#[path = "common/mod.rs"]
mod common;

use agora::baselines::{
    AirflowScheduler, CriticalPathScheduler, DagpsScheduler, ErnestGoal, MilpScheduler,
    Scheduler, StratusScheduler,
};
use agora::bench;
use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::generator::large_scale_dag;
use agora::dag::workloads::{dag1, dag2};
use agora::predictor::OraclePredictor;
use agora::solver::objective::Objective;
use agora::solver::sgs::{priorities, serial_sgs, Rule};
use agora::solver::{anneal, portfolio_anneal, AnnealParams, Goal, Problem};
use agora::util::{fmt_cost, fmt_duration, Rng};
use agora::Predictor;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench::header(
        "Figure 7",
        "end-to-end runtime & cost: Airflow / AGORA / Ernest+CP / MILP / DAGPS / Stratus",
    );
    println!(
        "mode: {} | seed = {}; all plans executed with identical run noise\n",
        if smoke { "smoke (--smoke)" } else { "full" },
        common::SEED
    );

    let dag_set: &[(&str, fn() -> agora::Dag)] = if smoke {
        &[("DAG1", dag1 as fn() -> agora::Dag)]
    } else {
        &[("DAG1", dag1 as fn() -> agora::Dag), ("DAG2", dag2)]
    };
    for &(dag_name, dag_fn) in dag_set {
        let mut rng = Rng::new(common::SEED);
        let (p, dags) = common::learned_problem(vec![dag_fn()], &mut rng);

        // Baseline anchor: default Airflow.
        let airflow = AirflowScheduler::default().schedule(&p).expect("airflow");
        let (air_m, air_c) = common::realize(&p, &dags, &airflow);

        for goal in [Goal::Balanced, Goal::Runtime, Goal::Cost] {
            println!("\n-- {dag_name}, goal = {} --", goal.name());
            let mut rows = Vec::new();
            let mut push = |name: &str, m: f64, c: f64| {
                rows.push(vec![
                    name.to_string(),
                    fmt_duration(m),
                    fmt_cost(c),
                    bench::pct(air_m, m),
                    bench::pct(air_c, c),
                ]);
            };
            push("airflow", air_m, air_c);

            let plan = if smoke {
                common::agora_plan_quick(&p, goal, air_m)
            } else {
                common::agora_plan(&p, goal, air_m)
            };
            let (m, c) = common::realize(&p, &dags, &plan.schedule);
            push("AGORA", m, c);

            let cp = CriticalPathScheduler::with_ernest(ErnestGoal(goal))
                .schedule(&p)
                .expect("ernest+cp");
            let (m, c) = common::realize(&p, &dags, &cp);
            push("ernest+cp", m, c);

            let milp = MilpScheduler::with_ernest(ErnestGoal(goal))
                .schedule(&p)
                .expect("ernest+milp");
            let (m, c) = common::realize(&p, &dags, &milp);
            push("ernest+milp", m, c);

            let dagps = DagpsScheduler::with_ernest(ErnestGoal(goal))
                .schedule(&p)
                .expect("ernest+dagps");
            let (m, c) = common::realize(&p, &dags, &dagps);
            push("ernest+dagps", m, c);

            if goal == Goal::Cost {
                // Stratus only optimizes cost (paper: implemented
                // "specially for cost").
                let stratus = StratusScheduler::default().schedule(&p).expect("stratus");
                let (m, c) = common::realize(&p, &dags, &stratus);
                push("stratus", m, c);
            }

            bench::table(
                &["policy", "runtime", "cost", "d-runtime", "d-cost"],
                &rows,
            );
        }
    }

    seeding_duel(smoke);

    println!(
        "\npaper shape targets: balanced -> AGORA better on BOTH axes \
         (runtime -15..-24%, cost -35..-50%); runtime goal -> -36..-45% runtime \
         at higher cost; cost goal -> lowest cost (-71..-78%) at comparable \
         runtime; Stratus fast but pricier than AGORA; Ernest+CP/MILP can be \
         worse than unoptimized Airflow; Ernest+DAGPS sits between them on \
         topology-heavy DAGs."
    );
}

/// Troublesome-seeded vs unseeded portfolio on a wide-fan-out DAG.
///
/// Both sides charge the same budget (2 chains, same iteration cap,
/// exchange off). The zero-iteration row is asserted: the seeded
/// portfolio starts from {default, DAGPS reseed} and keeps the better,
/// so it can never lose to the unseeded start. The deeper row shows the
/// same duel with the walks running; it is informational (SA variance),
/// printed so drifts are visible in CI logs.
fn seeding_duel(smoke: bool) {
    let tasks = if smoke { 150 } else { 400 };
    println!("\n-- troublesome-seeded vs unseeded portfolio, {tasks}-task wide fan-out --");
    let mut rng = Rng::new(common::SEED);
    let dag = large_scale_dag(&mut rng, "wide", tasks);
    let space = ConfigSpace::standard();
    let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let p = Problem::new(
        &[dag],
        &[0.0],
        Capacity::micro(),
        space,
        grid,
        CostModel::OnDemand,
    );
    let init = vec![p.feasible[0]; p.len()];
    let prio = priorities(&p, &init, Rule::CriticalPath);
    let s0 = serial_sgs(&p, &init, &prio).expect("feasible default assignment");
    let objective = Objective::new(Goal::Balanced, s0.makespan(&p), s0.cost(&p));

    // Pinned T0: no warmup proposals, so a zero-iteration run is exactly
    // the evaluation of its start point(s) — that is what makes the
    // structural row below provable rather than statistical.
    let run = |iters: usize, seeded: bool| {
        let params = AnnealParams {
            t0: Some(0.05),
            max_iters: iters,
            patience: iters.max(1),
            exchange_interval: 0,
            troublesome_seed: seeded,
            ..AnnealParams::fast()
        };
        portfolio_anneal(&p, &objective, &init, &params, 2, common::SEED)
    };

    let mut rows = Vec::new();
    let mut duel = |label: &str, iters: usize| -> (f64, f64) {
        let seeded = run(iters, true);
        let unseeded = run(iters, false);
        rows.push(vec![
            label.to_string(),
            format!("{:.5}", seeded.energy),
            format!("{:.5}", unseeded.energy),
            fmt_duration(seeded.makespan),
            fmt_duration(unseeded.makespan),
        ]);
        (seeded.energy, unseeded.energy)
    };

    // Structural row: zero iterations — pure start-point comparison.
    let (se, ue) = duel("start points (0 iters)", 0);
    assert!(
        se <= ue + 1e-12,
        "seeded portfolio start {se} must not lose to unseeded {ue} at equal budget"
    );
    // Informational row: the same duel with the walks running.
    let (label, searched_iters) = if smoke {
        ("searched (60 iters)", 60)
    } else {
        ("searched (300 iters)", 300)
    };
    duel(label, searched_iters);

    // Reference: the plain unseeded single chain at the deeper budget.
    let params = AnnealParams {
        max_iters: searched_iters,
        patience: searched_iters,
        ..AnnealParams::fast()
    };
    let mut chain_rng = Rng::new(common::SEED);
    let single = anneal(&p, &objective, &init, &params, &mut chain_rng);
    rows.push(vec![
        "single chain (ref)".to_string(),
        "-".to_string(),
        format!("{:.5}", single.energy),
        "-".to_string(),
        fmt_duration(single.makespan),
    ]);

    bench::table(
        &["budget", "seeded energy", "unseeded energy", "seeded runtime", "unseeded runtime"],
        &rows,
    );
    println!("seeded <= unseeded asserted at the structural 0-iteration row");
}
