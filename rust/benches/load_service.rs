//! Seeded load generator for the actor-style control plane
//! (`coordinator::service`): hundreds of tenants submit a workload mix
//! (`dag::workloads` plus small `dag::generator::large_scale_dag`
//! bursts) with Poisson inter-arrival times from concurrent generator
//! threads, against a bounded-queue, multi-worker service under
//! continuous admission.
//!
//! Reported: submissions, served replies, dropped replies (must be 0 —
//! every admitted ticket is answered), backpressure rejections
//! (resubmitted until admitted), rounds, wall-clock throughput and the
//! service's own status digests (queue delay percentiles, utilization,
//! optimizer overhead). The same numbers land in `BENCH_service.json`
//! at the repo root so the control-plane trajectory is diffable across
//! PRs.
//!
//! The arrival process and the workload mix are seeded, but wall-clock
//! interleaving makes batch composition host-dependent — this bench
//! measures the control plane's throughput and liveness, not bit-level
//! round contents (that pin lives in `tests/control_plane.rs`).
//!
//! `cargo bench --bench load_service -- --smoke` runs the small
//! configuration (120 tenants) and asserts nonzero throughput with zero
//! dropped replies — the CI liveness gate.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use agora::bench;
use agora::coordinator::service::{Service, ServiceConfig};
use agora::coordinator::{Admission, SubmitError};
use agora::dag::generator::large_scale_dag;
use agora::dag::workloads::{dag1, dag2, fig1_dag};
use agora::util::{Json, Rng};
use agora::Dag;

const SEED: u64 = 2022;
/// Tasks per synthetic large-scale burst DAG (kept small so a round's
/// co-optimization stays in the fast-params envelope).
const BURST_TASKS: usize = 16;

/// The workload mix: the three paper workloads plus an occasional
/// generator burst, drawn from the generator thread's seeded stream.
fn synth_dag(rng: &mut Rng, tenant: usize, s: usize) -> Dag {
    match rng.uniform(0.0, 4.0) as usize {
        0 => dag1(),
        1 => dag2(),
        2 => fig1_dag(),
        _ => large_scale_dag(
            &mut Rng::new(SEED ^ (tenant as u64 * 7919 + s as u64)),
            &format!("burst{tenant}x{s}"),
            BURST_TASKS,
        ),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench::header(
        "Service load",
        "Poisson multi-tenant load against the actor-style control plane",
    );
    let (tenants, per_tenant, gens) = if smoke { (120, 1, 6) } else { (300, 2, 8) };
    let submissions = tenants * per_tenant;
    println!(
        "mode: {} | {tenants} tenants x {per_tenant} submission(s) from {gens} generator threads",
        if smoke { "smoke (--smoke)" } else { "full" }
    );

    let config = ServiceConfig {
        batch_window: Duration::from_millis(25),
        max_queue: 8,
        max_batch: 16,
        workers: 2,
        queue_bound: 4,
        admission: Admission::Continuous,
        seed: SEED,
        ..Default::default()
    };
    let (workers, queue_bound, max_batch) = (config.workers, config.queue_bound, config.max_batch);
    let service = Service::start(config);
    let handle = service.handle();

    let rejected = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for g in 0..gens {
        let handle = service.handle();
        let rejected = rejected.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(SEED ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(g as u64 + 1));
            let mut tickets = Vec::new();
            for t in (g..tenants).step_by(gens) {
                let tenant = format!("tenant{t:04}");
                for s in 0..per_tenant {
                    let dag = synth_dag(&mut rng, t, s);
                    // Poisson arrivals: exponential inter-arrival gaps,
                    // clamped so one long draw cannot stall the run.
                    let gap_ms = rng.exponential(2.0).min(20.0);
                    std::thread::sleep(Duration::from_secs_f64(gap_ms / 1e3));
                    loop {
                        match handle.submit(&tenant, dag.clone()) {
                            Ok(ticket) => {
                                tickets.push(ticket);
                                break;
                            }
                            Err(SubmitError::QueueFull { .. }) => {
                                // Explicit backpressure: back off briefly
                                // and resubmit — nothing is dropped.
                                rejected.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            Err(SubmitError::ShuttingDown) => {
                                panic!("service shut down mid-load");
                            }
                        }
                    }
                }
            }
            let mut served = 0usize;
            let mut dropped = 0usize;
            for ticket in tickets {
                match ticket.recv_timeout(Duration::from_secs(600)) {
                    Ok(r) => {
                        assert!(r.completion > 0.0 && r.cost > 0.0);
                        served += 1;
                    }
                    Err(_) => dropped += 1,
                }
            }
            (served, dropped)
        }));
    }

    let mut served = 0usize;
    let mut dropped = 0usize;
    for j in joins {
        let (s, d) = j.join().expect("generator thread");
        served += s;
        dropped += d;
    }
    let elapsed = t0.elapsed();
    let rejected = rejected.load(Ordering::Relaxed);
    let status = handle.status();
    let rounds = service.shutdown().expect("clean shutdown");
    let throughput = served as f64 / elapsed.as_secs_f64().max(1e-9);

    bench::table(
        &[
            "submissions",
            "served",
            "dropped",
            "backpressure",
            "rounds",
            "elapsed (s)",
            "dags/s",
        ],
        &[vec![
            submissions.to_string(),
            served.to_string(),
            dropped.to_string(),
            rejected.to_string(),
            rounds.to_string(),
            format!("{:.2}", elapsed.as_secs_f64()),
            format!("{throughput:.1}"),
        ]],
    );
    println!(
        "queue delay p50 {:.3}s p95 {:.3}s | mean completion {:.1}s | utilization {:.2} | optimizer {:.2}s",
        status.p50_queue_delay,
        status.p95_queue_delay,
        status.stats.mean_completion,
        status.stats.utilization,
        status.optimizer_overhead.as_secs_f64()
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("load_service")),
        ("seed", Json::num(SEED as f64)),
        ("smoke", Json::Bool(smoke)),
        ("tenants", Json::num(tenants as f64)),
        ("submissions", Json::num(submissions as f64)),
        ("served", Json::num(served as f64)),
        ("dropped", Json::num(dropped as f64)),
        ("backpressure_rejections", Json::num(rejected as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("rounds_retried", Json::num(status.rounds_retried as f64)),
        ("workers", Json::num(workers as f64)),
        ("queue_bound", Json::num(queue_bound as f64)),
        ("max_batch", Json::num(max_batch as f64)),
        ("elapsed_s", Json::num(elapsed.as_secs_f64())),
        ("throughput_dags_per_s", Json::num(throughput)),
        ("p50_queue_delay_s", Json::num(status.p50_queue_delay)),
        ("p95_queue_delay_s", Json::num(status.p95_queue_delay)),
        ("mean_completion_s", Json::num(status.stats.mean_completion)),
        ("utilization", Json::num(status.stats.utilization)),
        (
            "optimizer_overhead_s",
            Json::num(status.optimizer_overhead.as_secs_f64()),
        ),
    ]);
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_service.json");
    match std::fs::write(&out, doc.to_pretty() + "\n") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }

    // Liveness gate (CI runs the smoke mode): every admitted ticket was
    // answered and the control plane made forward progress.
    assert_eq!(dropped, 0, "control plane dropped {dropped} replies");
    assert_eq!(served, submissions, "served {served} of {submissions}");
    assert!(rounds >= 1, "no rounds committed");
    assert!(throughput > 0.0, "zero throughput");
    println!("load OK: {served} served, 0 dropped, {rounds} rounds");
}
