//! Fig. 2 — Ernest runtime predictions for the four example jobs across
//! instance types and node counts.
//!
//! Regenerates the four panels: predicted runtime vs number of nodes for
//! each m5 instance type, using the learned (Ernest-style) predictor
//! trained on profiling runs. Also reports prediction error vs ground
//! truth (the paper quotes <20% for Ernest) and the expected curve
//! shapes: diminishing returns everywhere, negative scaling for
//! Sentiment Analysis on large m5.4xlarge counts.

#[path = "common/mod.rs"]
mod common;

use agora::bench;
use agora::cluster::catalog::{table1, M5_CATALOG};
use agora::cluster::{Config, ConfigSpace};
use agora::dag::workloads::ALL_JOBS;
use agora::predictor::{bootstrap_history, default_profiling_configs, mape};
use agora::util::Rng;
use agora::{LearnedPredictor, Predictor};

fn main() {
    bench::header(
        "Figure 2",
        "Ernest runtime prediction on four example jobs (predicted seconds)",
    );
    print!("{}", table1());
    println!("seed = {}", common::SEED);

    let mut rng = Rng::new(common::SEED);
    let logs: Vec<_> = ALL_JOBS
        .iter()
        .map(|j| bootstrap_history(j.name(), &j.profile(), &default_profiling_configs(), &mut rng))
        .collect();
    let predictor = LearnedPredictor::fit(&logs);

    let nodes = [1u32, 2, 4, 6, 8, 10, 12, 16];
    for (j, job) in ALL_JOBS.iter().enumerate() {
        let labels: Vec<&str> = M5_CATALOG.iter().map(|it| it.name).collect();
        let points: Vec<(f64, Vec<f64>)> = nodes
            .iter()
            .map(|&n| {
                let ys: Vec<f64> = (0..M5_CATALOG.len())
                    .map(|inst| {
                        let cfg = Config {
                            instance: inst,
                            nodes: n,
                            spark: 1,
                        };
                        agora::predictor::model_runtime(&predictor.fits[j], &cfg)
                    })
                    .collect();
                (n as f64, ys)
            })
            .collect();
        bench::series(job.name(), "nodes", &labels, &points);
    }

    // Quantitative checks the paper's text claims.
    let space = ConfigSpace::standard();
    let grid = predictor.predict(&space);
    let profiles: Vec<_> = ALL_JOBS.iter().map(|j| j.profile()).collect();
    let err = mape(&grid, &profiles, &space);
    println!("\nprediction MAPE vs ground truth: {:.1}% (Ernest paper: <20%)", err * 100.0);

    // Shape assertions (also exercised by tests).
    let sentiment = &predictor.fits[1];
    let r8 = agora::predictor::model_runtime(sentiment, &Config { instance: 0, nodes: 8, spark: 1 });
    let r16 = agora::predictor::model_runtime(sentiment, &Config { instance: 0, nodes: 16, spark: 1 });
    println!(
        "sentiment-analysis negative scaling on m5.4xlarge: r(16)={:.0}s vs r(8)={:.0}s -> {}",
        r16,
        r8,
        if r16 > r8 { "REPRODUCED" } else { "not visible at this seed" }
    );

    let timing = bench::measure("full-grid prediction (host)", 2, 10, || {
        let _ = predictor.predict(&space);
    });
    println!(
        "\ngrid prediction latency: {:.3} ms mean over {} reps ({} tasks x {} configs)",
        timing.mean_ms(),
        timing.reps,
        ALL_JOBS.len(),
        space.len()
    );
}
