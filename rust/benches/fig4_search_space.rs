//! Fig. 4 — search space and brute-force solve time grow exponentially
//! with the number of jobs in a DAG.
//!
//! Left panel: search-space size vs #jobs. Right panel: BF co-optimize
//! wall-clock vs #jobs (with a time cap; incomplete runs are marked).
//! Also prints AGORA's solve time on the same instances — the overhead
//! argument of §4.3/§5.4.

#[path = "common/mod.rs"]
mod common;

use std::time::Duration;

use agora::bench;
use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::workloads::{JobKind, ALL_JOBS};
use agora::dag::{Dag, Task};
use agora::predictor::OraclePredictor;
use agora::solver::brute_force::{brute_force, search_space_size};
use agora::solver::cp::Limits;
use agora::solver::{anneal, portfolio_anneal, AnnealParams, Goal, Objective, Problem};
use agora::util::Rng;
use agora::Predictor;

/// Fan-out pipeline with `jobs` tasks (1 ingest + N-1 parallel ML jobs),
/// the paper's "single DAG with increasing number of jobs".
fn pipeline(jobs: usize) -> Dag {
    let mut tasks: Vec<Task> = vec![JobKind::IndexAnalysis.task()];
    let mut edges = Vec::new();
    for i in 1..jobs {
        tasks.push(ALL_JOBS[i % ALL_JOBS.len()].task());
        edges.push((0, i));
    }
    Dag::new(&format!("pipe{jobs}"), tasks, edges).unwrap()
}

fn main() {
    bench::header(
        "Figure 4",
        "search space + solve time vs number of jobs (BF co-optimize)",
    );

    // m5.4xlarge ladder only, like the §3 study.
    let mut space = ConfigSpace::with_ladder(&[1, 2, 4, 8, 16]);
    space.configs.retain(|c| c.instance == 0 && c.spark == 1);
    println!("configs per task: {} (m5.4xlarge ladder)", space.len());
    let cap = Duration::from_secs(20);
    println!("BF time cap per instance: {cap:?}\n");

    let mut rows = Vec::new();
    for jobs in 1..=6 {
        let dag = pipeline(jobs);
        let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let dags = vec![dag];
        let p = Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space.clone(),
            grid,
            CostModel::OnDemand,
        );
        let c0 = p.feasible[0];
        let base = {
            let (s, _) = agora::solver::CpSolver::new(Limits::default())
                .solve(&p, &vec![c0; p.len()])
                .expect("feasible default assignment");
            (s.makespan(&p), s.cost(&p))
        };
        let obj = Objective::new(Goal::Runtime, base.0, base.1);

        let t0 = std::time::Instant::now();
        let bf = brute_force(&p, &obj, Limits::default(), cap);
        let bf_time = t0.elapsed();

        // T0 pinned on both sides (no uncounted warmup evaluations) and
        // patience >= max_iters (no early stop), so the 1-chain vs
        // 4-chain budgets match exactly.
        let sa_params = AnnealParams {
            t0: Some(0.05),
            patience: AnnealParams::fast().max_iters,
            ..AnnealParams::fast()
        };
        let t1 = std::time::Instant::now();
        let mut rng = Rng::new(common::SEED);
        let sa = anneal(&p, &obj, &vec![c0; p.len()], &sa_params, &mut rng);
        let sa_time = t1.elapsed();

        // Portfolio at the same total budget split 4 ways.
        let t2 = std::time::Instant::now();
        let quad_params = AnnealParams {
            max_iters: sa_params.max_iters / 4,
            ..sa_params.clone()
        };
        let quad = portfolio_anneal(&p, &obj, &vec![c0; p.len()], &quad_params, 4, common::SEED);
        let quad_time = t2.elapsed();

        // Adaptive engine (calibrated T0 + equilibrium loops + restarts)
        // at the same charged budget: the warmup samples and restart
        // reseeds are billed against the same max_iters the fixed chain
        // spends, so the gap column is an equal-cost comparison.
        let adaptive_params = AnnealParams {
            patience: AnnealParams::fast().max_iters,
            ..AnnealParams::fast()
        }
        .adaptive();
        let mut arng = Rng::new(common::SEED);
        let adaptive = anneal(&p, &obj, &vec![c0; p.len()], &adaptive_params, &mut arng);

        rows.push(vec![
            jobs.to_string(),
            format!("{:.1e}", search_space_size(jobs, space.len())),
            format!(
                "{:.3}s{}",
                bf_time.as_secs_f64(),
                if bf.complete { "" } else { " (capped)" }
            ),
            format!("{}", bf.evaluated),
            format!("{:.3}s", sa_time.as_secs_f64()),
            format!("{:+.1}%", (sa.energy - bf.energy) * 100.0),
            format!(
                "{:.3}s ({})",
                quad_time.as_secs_f64(),
                bench::speedup(sa_time, quad_time)
            ),
            format!("{:+.1}%", (quad.energy - bf.energy) * 100.0),
            format!("{:+.1}%", (adaptive.energy - bf.energy) * 100.0),
        ]);
    }
    bench::table(
        &[
            "jobs",
            "search space",
            "BF solve time",
            "BF evaluated",
            "AGORA time",
            "AGORA gap vs BF",
            "portfolio x4 time",
            "portfolio gap vs BF",
            "adaptive gap vs BF",
        ],
        &rows,
    );
    println!(
        "\npaper: search space and solve time grow exponentially with jobs;\n\
         AGORA (SA x CP) stays sub-second while tracking the BF optimum."
    );
}
