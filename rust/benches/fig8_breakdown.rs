//! Fig. 8 — performance breakdown: Predictor-only, Scheduler-only,
//! AGORA-separate (both, independently), and full AGORA co-optimization,
//! on DAG1 and DAG2 at the balanced goal.
//!
//! Paper's findings to reproduce:
//!   * DAG1: Predictor contributes more than Scheduler; DAG2: opposite
//!     (more parallelism for the scheduler to exploit).
//!   * AGORA-separate can be WORSE than single-component modes.
//!   * Full co-optimization beats separate on both axes
//!     (paper: 4.0% faster / 44.4% cheaper on DAG1; 33.8% / 49.8% on DAG2).

#[path = "common/mod.rs"]
mod common;

use agora::baselines::{AirflowScheduler, Scheduler};
use agora::bench;
use agora::dag::workloads::{dag1, dag2};
use agora::solver::{Agora, AgoraOptions, Goal, Mode};
use agora::util::{fmt_cost, fmt_duration, Rng};

fn main() {
    bench::header(
        "Figure 8",
        "AGORA component breakdown at the balanced goal (realized on the simulator)",
    );

    for (dag_name, dag_fn) in [("DAG1", dag1 as fn() -> agora::Dag), ("DAG2", dag2)] {
        let mut rng = Rng::new(common::SEED);
        let (p, dags) = common::learned_problem(vec![dag_fn()], &mut rng);
        let airflow = AirflowScheduler::default().schedule(&p).expect("airflow");
        let (air_m, air_c) = common::realize(&p, &dags, &airflow);

        println!("\n-- {dag_name} (airflow anchor: {} / {}) --", fmt_duration(air_m), fmt_cost(air_c));
        let mut rows = Vec::new();
        let mut results = Vec::new();
        for mode in [
            Mode::PredictorOnly,
            Mode::SchedulerOnly,
            Mode::Separate,
            Mode::CoOptimize,
        ] {
            let plan = Agora::new(AgoraOptions {
                goal: Goal::Balanced,
                mode,
                seed: common::SEED,
                ..Default::default()
            })
            .optimize(&p);
            let (m, c) = common::realize(&p, &dags, &plan.schedule);
            results.push((mode, m, c));
            rows.push(vec![
                mode.name().to_string(),
                fmt_duration(m),
                fmt_cost(c),
                bench::pct(air_m, m),
                bench::pct(air_c, c),
            ]);
        }
        bench::table(&["mode", "runtime", "cost", "d-runtime", "d-cost"], &rows);

        let sep = results.iter().find(|r| r.0 == Mode::Separate).unwrap();
        let co = results.iter().find(|r| r.0 == Mode::CoOptimize).unwrap();
        println!(
            "co-optimize vs separate: {} runtime, {} cost (paper: DAG1 -4.0%/-44.4%, DAG2 -33.8%/-49.8%)",
            bench::pct(sep.1, co.1),
            bench::pct(sep.2, co.2)
        );
    }
}
