//! Fig. 10 — optimization overhead vs runtime benefit as the problem
//! grows from 1 to N random DAGs (10 tasks each, width 4, depth 3-5).
//!
//! Paper's claim: overhead grows with problem size (tens of seconds to
//! ~1000 s at 200 tasks on their machine) but the runtime benefit grows
//! much faster, so no problem size lands in the overhead >= benefit
//! region. We sweep 1..=N DAGs and report both quantities plus the
//! predicted-improvement trace the paper plots.

#[path = "common/mod.rs"]
mod common;

use agora::baselines::{AirflowScheduler, Scheduler};
use agora::bench;
use agora::dag::generator::fig10_batch;
use agora::solver::{Agora, AgoraOptions, Goal, Mode};
use agora::util::{fmt_duration, Rng};

fn main() {
    bench::header(
        "Figure 10",
        "optimizer overhead vs runtime benefit, 10..N-task multi-DAG problems",
    );
    let dag_counts: Vec<usize> = if std::env::var_os("AGORA_BENCH_FULL").is_some() {
        vec![1, 2, 4, 8, 12, 16, 20]
    } else {
        vec![1, 2, 4, 8, 12]
    };
    println!(
        "sweep: {:?} DAGs x 10 tasks (set AGORA_BENCH_FULL=1 for the 200-task point)\n",
        dag_counts
    );

    let mut rows = Vec::new();
    for &n in &dag_counts {
        let mut rng = Rng::new(common::SEED + n as u64);
        let dags = fig10_batch(&mut rng, n);
        let (p, _dags) = common::learned_problem(dags, &mut rng);

        // Baseline runtime: default Airflow plan (predicted).
        let airflow = AirflowScheduler::default().schedule(&p).expect("airflow");
        let base_makespan = airflow.makespan(&p);

        let t0 = std::time::Instant::now();
        let plan = Agora::new(AgoraOptions {
            goal: Goal::Runtime,
            mode: Mode::CoOptimize,
            seed: common::SEED,
            ..Default::default()
        })
        .optimize(&p);
        let overhead = t0.elapsed();
        let benefit = base_makespan - plan.makespan;

        rows.push(vec![
            format!("{n}"),
            format!("{}", p.len()),
            format!("{:.2}s", overhead.as_secs_f64()),
            fmt_duration(benefit.max(0.0)),
            format!("{:.1}x", benefit.max(0.0) / overhead.as_secs_f64().max(1e-9)),
            if (benefit) > overhead.as_secs_f64() {
                "benefit > overhead".into()
            } else {
                "SHADED REGION".into()
            },
        ]);
    }
    bench::table(
        &["DAGs", "tasks", "overhead", "runtime benefit", "benefit/overhead", "region"],
        &rows,
    );
    println!(
        "\npaper: no problem size falls in the shaded (overhead >= benefit) region;\n\
         micro-DAG overheads were ~35-45 s on the authors' solver vs seconds here\n\
         (in-repo CP solver, single core — see EXPERIMENTS.md)."
    );
}
