//! §3 motivational study — Table 2 + Fig. 3: separate optimization
//! (Ernest VM selection + TetriSched-style scheduling) vs brute-force
//! co-optimization on the Fig. 1 DAG.
//!
//! Paper's finding: BF co-optimize reaches ~40% better runtime and cost
//! because the scheduler can overlap deliberately-slowed tasks. We
//! reproduce the whole study: the exhaustive search, the resulting VM
//! selections (Table 2), the schedule breakdown, and the improvement.

#[path = "common/mod.rs"]
mod common;

use std::time::Duration;

use agora::bench;
use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::workloads::fig1_dag;
use agora::predictor::OraclePredictor;
use agora::solver::brute_force::{brute_force, search_space_size};
use agora::solver::cp::{CpSolver, Limits};
use agora::solver::{Goal, Objective, Problem};
use agora::util::{fmt_cost, fmt_duration};
use agora::Predictor;

fn main() {
    bench::header(
        "Table 2 + Figure 3",
        "separate (Ernest+TetriSched) vs brute-force co-optimization, Fig. 1 DAG",
    );

    // The §3 study uses m5.4xlarge ladders (Table 2 shows only that
    // type); restrict the space accordingly so exhaustive search matches
    // the paper's setup.
    let dag = fig1_dag();
    let mut space = ConfigSpace::with_ladder(&[1, 2, 4, 6, 8, 10, 12, 16]);
    space.configs.retain(|c| c.instance == 0 && c.spark == 1);
    let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
    let grid = OraclePredictor {
        profiles: profiles.clone(),
    }
    .predict(&space);
    let dags = vec![dag];
    let p = Problem::new(
        &dags,
        &[0.0],
        Capacity::micro(),
        space,
        grid,
        CostModel::OnDemand,
    );
    println!(
        "search space: {} tasks x {} configs = {:.1e} assignments (x schedules; Fig. 4 measures the growth)",
        p.len(),
        p.feasible.len(),
        search_space_size(p.len(), p.feasible.len())
    );

    // --- separate: Ernest per-task runtime-optimal + exact scheduling ---
    let ernest_sel = agora::baselines::ernest_selection(
        &p,
        agora::baselines::ErnestGoal(Goal::Runtime),
    );
    let (sep_sched, _) = CpSolver::new(Limits::default())
        .solve(&p, &ernest_sel)
        .expect("ernest selections draw from Problem::feasible");
    let sep_makespan = sep_sched.makespan(&p);
    let sep_cost = sep_sched.cost(&p);

    // --- BF co-optimize: exhaustive over configs, exact inner solve ---
    let objective = Objective::new(Goal::Runtime, sep_makespan, sep_cost);
    let t0 = std::time::Instant::now();
    let bf = brute_force(&p, &objective, Limits::default(), Duration::from_secs(600));
    println!(
        "\nbrute force: {} assignments evaluated in {:?} (complete = {})",
        bf.evaluated,
        t0.elapsed(),
        bf.complete
    );

    // --- Table 2 ---
    println!("\nTable 2. VM selections (nodes x m5.4xlarge)");
    let rows: Vec<Vec<String>> = (0..p.len())
        .map(|t| {
            vec![
                p.tasks[t].name.clone(),
                p.config(ernest_sel[t]).label(),
                p.config(bf.schedule.assignment[t]).label(),
            ]
        })
        .collect();
    bench::table(&["job", "Ernest", "BF co-optimize"], &rows);

    // --- Fig. 3a/3b: schedule breakdowns ---
    println!("\nFig. 3a — separate (Ernest + exact scheduling):");
    println!("{}", sep_sched.render(&p));
    println!("Fig. 3b — BF co-optimize:");
    println!("{}", bf.schedule.render(&p));

    // --- Fig. 3c: runtime + cost ---
    println!("Fig. 3c — end-to-end comparison");
    bench::table(
        &["approach", "runtime", "cost", "vs separate"],
        &[
            vec![
                "separate".into(),
                fmt_duration(sep_makespan),
                fmt_cost(sep_cost),
                "--".into(),
            ],
            vec![
                "BF co-optimize".into(),
                fmt_duration(bf.makespan),
                fmt_cost(bf.cost),
                format!(
                    "{} runtime, {} cost",
                    bench::pct(sep_makespan, bf.makespan),
                    bench::pct(sep_cost, bf.cost)
                ),
            ],
        ],
    );
    println!(
        "\npaper: ~40% improvement in runtime and cost; reproduced: {} runtime, {} cost",
        bench::pct(sep_makespan, bf.makespan),
        bench::pct(sep_cost, bf.cost)
    );
}
