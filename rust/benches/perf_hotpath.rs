//! §Perf — hot-path microbenchmarks for the L3 coordinator.
//!
//! Not a paper figure: this target measures the pieces the optimizer
//! spends its time in, and is the measurement harness for the
//! performance pass recorded in EXPERIMENTS.md §Perf:
//!   * serial SGS placement (the innermost loop),
//!   * one CP solve at annealing limits,
//!   * one full annealing iteration (propose + solve + accept),
//!   * full co-optimization of DAG1+DAG2,
//!   * host-predictor grid construction,
//!   * PJRT predictor grid construction (when artifacts are present),
//!   * adaptive vs fixed search engine at an equal charged budget, and
//!     the destructive UB-ladder vs the one-shot exact CP solve.
//!
//! `cargo bench --bench perf_hotpath -- --smoke` skips the timing rows
//! and runs only the deterministic equal-budget quality duel — the CI
//! pin that the adaptive engine (calibrated T0 + equilibrium loops +
//! restart-on-stall) is at least as good as the fixed engine at the
//! same evaluation budget. Both modes write `BENCH_search.json` at the
//! repo root.

#[path = "common/mod.rs"]
mod common;

use std::path::Path;

use agora::bench;
use agora::dag::workloads::{dag1, dag2};
use agora::runtime::{ArtifactManifest, Engine, PjrtPredictor};
use agora::solver::cp::{CpSolver, Limits};
use agora::solver::sgs;
use agora::solver::{anneal, portfolio_anneal, Agora, AgoraOptions, AnnealParams, Goal, Objective};
use agora::util::{Json, Rng};
use agora::{LearnedPredictor, Predictor};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench::header("Perf", "L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf harness)");
    if smoke {
        println!("mode: smoke (--smoke) — equal-budget search-engine duel only\n");
    }

    let mut rng = Rng::new(common::SEED);
    let (p, dags) = common::learned_problem(vec![dag1(), dag2()], &mut rng);
    let c0 = Agora::default_config(&p.space);
    let assignment = vec![c0; p.len()];
    let _ = &dags;

    if !smoke {
        timing_rows(&p, &dags, &assignment);
    }
    search_engine_duel(smoke);
}

/// The historical microbenchmark rows (skipped under `--smoke`).
fn timing_rows(p: &agora::solver::Problem, dags: &[agora::Dag], assignment: &[usize]) {
    let assignment = assignment.to_vec();
    let mut results = Vec::new();

    let prio = sgs::priorities(p, &assignment, sgs::Rule::CriticalPath);
    results.push(bench::measure("serial SGS (16 tasks)", 50, 500, || {
        let s = sgs::serial_sgs(&p, &assignment, &prio).expect("feasible assignment");
        std::hint::black_box(s.start[0]);
    }));

    let solver = CpSolver::new(Limits::inner_loop());
    results.push(bench::measure("CP solve @ inner-loop limits", 10, 100, || {
        let (s, _) = solver.solve(&p, &assignment).expect("feasible assignment");
        std::hint::black_box(s.start[0]);
    }));

    let obj = Objective::new(Goal::Balanced, 3000.0, 8.0);
    results.push(bench::measure("anneal 50 iterations", 2, 10, || {
        let mut rng = Rng::new(7);
        let r = anneal(
            &p,
            &obj,
            &assignment,
            &AnnealParams {
                max_iters: 50,
                patience: 1000,
                ..Default::default()
            },
            &mut rng,
        );
        std::hint::black_box(r.energy);
    }));

    results.push(bench::measure("full co-optimize DAG1+DAG2", 1, 3, || {
        let plan = Agora::new(AgoraOptions {
            seed: 1,
            ..Default::default()
        })
        .optimize(&p);
        std::hint::black_box(plan.makespan);
    }));

    // Portfolio co-optimizer: equal total proposal budget, 1 vs 4 chains.
    // The single chain runs the whole budget sequentially; the portfolio
    // splits it across 4 concurrent diversified chains (half of them on
    // the incremental suffix-SGS evaluator), so wall-clock should drop by
    // >= 2x at matched solution quality. T0 is pinned so neither side
    // spends uncounted warmup-calibration evaluations; each chain's final
    // polish solve is charged to its own wall-clock.
    let budget = 400usize;
    let chain_of = |k: usize| AnnealParams {
        max_iters: budget / k,
        patience: budget, // no early stop: strict equal-budget comparison
        t0: Some(0.05),   // skip warmup calibration (uncounted evals)
        ..Default::default()
    };
    let single_params = chain_of(1);
    let quad_params = chain_of(4);
    let single_energy = portfolio_anneal(&p, &obj, &assignment, &single_params, 1, 2022).energy;
    let quad_energy = portfolio_anneal(&p, &obj, &assignment, &quad_params, 4, 2022).energy;
    let single_m = bench::measure("co-optimize 400 proposals, 1 chain", 1, 3, || {
        let r = portfolio_anneal(&p, &obj, &assignment, &single_params, 1, 2022);
        std::hint::black_box(r.energy);
    });
    let quad_m = bench::measure("co-optimize 4 x 100 proposals, 4 chains", 1, 3, || {
        let r = portfolio_anneal(&p, &obj, &assignment, &quad_params, 4, 2022);
        std::hint::black_box(r.energy);
    });
    results.push(single_m.clone());
    results.push(quad_m.clone());

    // Predictor paths.
    let logs = common::logs_for(&dags, &mut Rng::new(3));
    let space = agora::cluster::ConfigSpace::standard();
    results.push(bench::measure("host predictor fit+grid (16x96)", 5, 50, || {
        let pred = LearnedPredictor::fit(&logs);
        let g = pred.predict(&space);
        std::hint::black_box(g.get(0, 0));
    }));

    let artifacts = ArtifactManifest::default_dir();
    if artifacts.join("manifest.json").exists() {
        let engine = Engine::new(&artifacts).expect("artifacts load");
        let pjrt = PjrtPredictor::new(&engine);
        let fits: Vec<_> = logs.iter().map(LearnedPredictor::fit_task).collect();
        // warm the executable cache before timing
        let _ = pjrt.predict_fitted(&fits, &space).unwrap();
        results.push(bench::measure("PJRT predictor grid (cached exe)", 3, 30, || {
            let g = pjrt.predict_fitted(&fits, &space).unwrap();
            std::hint::black_box(g.get(0, 0));
        }));
        results.push(bench::measure("PJRT fit_predict (fused artifact)", 3, 30, || {
            let (g, _) = pjrt.fit_predict(&logs, &space).unwrap();
            std::hint::black_box(g.get(0, 0));
        }));
    } else {
        println!("(artifacts/ missing: run `make artifacts` for the PJRT rows)");
    }

    println!(
        "\nportfolio speedup (4 chains vs 1 chain, equal {budget}-proposal budget): {}",
        bench::speedup(single_m.mean, quad_m.mean)
    );
    println!(
        "solution quality at equal budget: single-chain energy {single_energy:.4}, \
         portfolio energy {quad_energy:.4} ({})",
        if quad_energy <= single_energy + 1e-9 {
            "portfolio at least as good"
        } else if quad_energy <= single_energy.min(0.0) * 0.95 {
            "within 5% of single-chain improvement"
        } else {
            "single chain ahead at this seed"
        }
    );

    println!();
    bench::table(
        &["hot path", "mean", "min", "max", "reps"],
        &results
            .iter()
            .map(|m| {
                vec![
                    m.name.clone(),
                    format!("{:.3} ms", m.mean_ms()),
                    format!("{:.3} ms", m.min.as_secs_f64() * 1e3),
                    format!("{:.3} ms", m.max.as_secs_f64() * 1e3),
                    m.reps.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

/// Equal-budget quality duel: the adaptive engine (calibrated T0,
/// equilibrium inner loops, restart-on-stall) vs the fixed engine at the
/// same `max_iters`. The adaptive side's warmup samples and restart
/// reseeds are charged against that budget, so neither engine sees more
/// evaluations than the other. Asserts the adaptive sum is at least as
/// good with >= 1 strict per-case win, checks the UB-ladder against the
/// one-shot exact CP solve, and writes `BENCH_search.json`.
fn search_engine_duel(smoke: bool) {
    let budget = 240usize;
    let seeds = [11u64, 12, 13];
    let instances = vec![
        ("dag1", vec![dag1()]),
        ("dag2", vec![dag2()]),
        ("dag1+dag2", vec![dag1(), dag2()]),
    ];
    let fixed_params = AnnealParams {
        max_iters: budget,
        patience: budget,
        t0: Some(0.05), // pinned: no warmup, the full budget is Metropolis moves
        ..Default::default()
    };
    let adaptive_params = AnnealParams {
        max_iters: budget,
        patience: budget,
        ..Default::default()
    }
    .adaptive();

    println!(
        "\n-- adaptive vs fixed search engine, {budget} charged evaluations, \
         {} instances x {} seeds --",
        instances.len(),
        seeds.len()
    );
    let mut rows = Vec::new();
    let mut cases = Vec::new();
    let (mut sum_fixed, mut sum_adaptive) = (0.0f64, 0.0f64);
    let mut strict_wins = 0usize;
    for (name, dags) in instances {
        let (p, _) = common::learned_problem(dags, &mut Rng::new(common::SEED));
        let c0 = Agora::default_config(&p.space);
        let init = vec![c0; p.len()];
        let (s0, _) = CpSolver::new(Limits::default())
            .solve(&p, &init)
            .expect("default assignment is feasible");
        let obj = Objective::new(Goal::Balanced, s0.makespan(&p), s0.cost(&p));
        for &seed in &seeds {
            let fixed = anneal(&p, &obj, &init, &fixed_params, &mut Rng::new(seed));
            let adaptive = anneal(&p, &obj, &init, &adaptive_params, &mut Rng::new(seed));
            sum_fixed += fixed.energy;
            sum_adaptive += adaptive.energy;
            let win = adaptive.energy < fixed.energy - 1e-9;
            strict_wins += win as usize;
            rows.push(vec![
                name.to_string(),
                seed.to_string(),
                format!("{:.4}", fixed.energy),
                format!("{:.4}", adaptive.energy),
                adaptive.stats.restarts.to_string(),
                adaptive
                    .stats
                    .calibrated_t0
                    .map(|t| format!("{t:.5}"))
                    .unwrap_or_default(),
            ]);
            cases.push(Json::obj(vec![
                ("instance", Json::str(name)),
                ("seed", Json::num(seed as f64)),
                ("fixed_energy", Json::num(fixed.energy)),
                ("adaptive_energy", Json::num(adaptive.energy)),
                ("fixed_evaluations", Json::num(fixed.stats.evaluations as f64)),
                (
                    "adaptive_evaluations",
                    Json::num(adaptive.stats.evaluations as f64),
                ),
                ("adaptive_restarts", Json::num(adaptive.stats.restarts as f64)),
                (
                    "calibrated_t0",
                    adaptive.stats.calibrated_t0.map(Json::num).unwrap_or(Json::Null),
                ),
            ]));
        }
    }
    bench::table(
        &["instance", "seed", "fixed energy", "adaptive energy", "restarts", "calibrated T0"],
        &rows,
    );
    println!(
        "\nsummed energy over all cases: fixed {sum_fixed:.4}, adaptive {sum_adaptive:.4} \
         ({strict_wins} strict adaptive wins)"
    );
    assert!(
        sum_adaptive <= sum_fixed + 1e-9,
        "adaptive engine lost the equal-budget duel: {sum_adaptive:.4} vs {sum_fixed:.4}"
    );
    assert!(
        strict_wins >= 1,
        "adaptive engine never strictly beat the fixed engine"
    );

    // UB-ladder vs one-shot exact: same proved optimum on the 16-task
    // figure workload.
    let (p, _) = common::learned_problem(vec![dag1(), dag2()], &mut Rng::new(common::SEED));
    let c0 = Agora::default_config(&p.space);
    let a0 = vec![c0; p.len()];
    let (exact_s, exact_stats) = CpSolver::new(Limits::exact())
        .solve(&p, &a0)
        .expect("feasible default assignment");
    let (ladder_s, ladder_stats) = CpSolver::new(Limits::ladder())
        .solve_ladder(&p, &a0)
        .expect("feasible default assignment");
    println!(
        "\nCP polish: exact makespan {:.2}s (proved {}), ladder makespan {:.2}s \
         (proved {}, {} rungs)",
        exact_s.makespan(&p),
        exact_stats.proved_optimal,
        ladder_s.makespan(&p),
        ladder_stats.proved_optimal,
        ladder_stats.rungs
    );
    if exact_stats.proved_optimal && ladder_stats.proved_optimal {
        assert!(
            (exact_s.makespan(&p) - ladder_s.makespan(&p)).abs() <= 1e-9,
            "ladder proved a different optimum: {} vs {}",
            ladder_s.makespan(&p),
            exact_s.makespan(&p)
        );
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("perf_hotpath")),
        ("provenance", Json::str("measured")),
        ("smoke", Json::Bool(smoke)),
        ("seed", Json::num(common::SEED as f64)),
        ("budget", Json::num(budget as f64)),
        ("sum_fixed_energy", Json::num(sum_fixed)),
        ("sum_adaptive_energy", Json::num(sum_adaptive)),
        ("strict_adaptive_wins", Json::num(strict_wins as f64)),
        ("cases", Json::Arr(cases)),
        (
            "ladder",
            Json::obj(vec![
                ("exact_makespan", Json::num(exact_s.makespan(&p))),
                ("ladder_makespan", Json::num(ladder_s.makespan(&p))),
                ("exact_proved", Json::Bool(exact_stats.proved_optimal)),
                ("ladder_proved", Json::Bool(ladder_stats.proved_optimal)),
                ("rungs", Json::num(ladder_stats.rungs as f64)),
            ]),
        ),
    ]);
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_search.json");
    match std::fs::write(&out, doc.to_pretty() + "\n") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
