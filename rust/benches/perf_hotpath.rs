//! §Perf — hot-path microbenchmarks for the L3 coordinator.
//!
//! Not a paper figure: this target measures the pieces the optimizer
//! spends its time in, and is the measurement harness for the
//! performance pass recorded in EXPERIMENTS.md §Perf:
//!   * serial SGS placement (the innermost loop),
//!   * one CP solve at annealing limits,
//!   * one full annealing iteration (propose + solve + accept),
//!   * full co-optimization of DAG1+DAG2,
//!   * host-predictor grid construction,
//!   * PJRT predictor grid construction (when artifacts are present).

#[path = "common/mod.rs"]
mod common;

use agora::bench;
use agora::dag::workloads::{dag1, dag2};
use agora::runtime::{ArtifactManifest, Engine, PjrtPredictor};
use agora::solver::cp::{CpSolver, Limits};
use agora::solver::sgs;
use agora::solver::{anneal, portfolio_anneal, Agora, AgoraOptions, AnnealParams, Goal, Objective};
use agora::util::Rng;
use agora::{LearnedPredictor, Predictor};

fn main() {
    bench::header("Perf", "L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf harness)");

    let mut rng = Rng::new(common::SEED);
    let (p, dags) = common::learned_problem(vec![dag1(), dag2()], &mut rng);
    let c0 = Agora::default_config(&p.space);
    let assignment = vec![c0; p.len()];
    let _ = &dags;

    let mut results = Vec::new();

    let prio = sgs::priorities(&p, &assignment, sgs::Rule::CriticalPath);
    results.push(bench::measure("serial SGS (16 tasks)", 50, 500, || {
        let s = sgs::serial_sgs(&p, &assignment, &prio).expect("feasible assignment");
        std::hint::black_box(s.start[0]);
    }));

    let solver = CpSolver::new(Limits::inner_loop());
    results.push(bench::measure("CP solve @ inner-loop limits", 10, 100, || {
        let (s, _) = solver.solve(&p, &assignment).expect("feasible assignment");
        std::hint::black_box(s.start[0]);
    }));

    let obj = Objective::new(Goal::Balanced, 3000.0, 8.0);
    results.push(bench::measure("anneal 50 iterations", 2, 10, || {
        let mut rng = Rng::new(7);
        let r = anneal(
            &p,
            &obj,
            &assignment,
            &AnnealParams {
                max_iters: 50,
                patience: 1000,
                ..Default::default()
            },
            &mut rng,
        );
        std::hint::black_box(r.energy);
    }));

    results.push(bench::measure("full co-optimize DAG1+DAG2", 1, 3, || {
        let plan = Agora::new(AgoraOptions {
            seed: 1,
            ..Default::default()
        })
        .optimize(&p);
        std::hint::black_box(plan.makespan);
    }));

    // Portfolio co-optimizer: equal total proposal budget, 1 vs 4 chains.
    // The single chain runs the whole budget sequentially; the portfolio
    // splits it across 4 concurrent diversified chains (half of them on
    // the incremental suffix-SGS evaluator), so wall-clock should drop by
    // >= 2x at matched solution quality. T0 is pinned so neither side
    // spends uncounted warmup-calibration evaluations; each chain's final
    // polish solve is charged to its own wall-clock.
    let budget = 400usize;
    let chain_of = |k: usize| AnnealParams {
        max_iters: budget / k,
        patience: budget, // no early stop: strict equal-budget comparison
        t0: Some(0.05),   // skip warmup calibration (uncounted evals)
        ..Default::default()
    };
    let single_params = chain_of(1);
    let quad_params = chain_of(4);
    let single_energy = portfolio_anneal(&p, &obj, &assignment, &single_params, 1, 2022).energy;
    let quad_energy = portfolio_anneal(&p, &obj, &assignment, &quad_params, 4, 2022).energy;
    let single_m = bench::measure("co-optimize 400 proposals, 1 chain", 1, 3, || {
        let r = portfolio_anneal(&p, &obj, &assignment, &single_params, 1, 2022);
        std::hint::black_box(r.energy);
    });
    let quad_m = bench::measure("co-optimize 4 x 100 proposals, 4 chains", 1, 3, || {
        let r = portfolio_anneal(&p, &obj, &assignment, &quad_params, 4, 2022);
        std::hint::black_box(r.energy);
    });
    results.push(single_m.clone());
    results.push(quad_m.clone());

    // Predictor paths.
    let logs = common::logs_for(&dags, &mut Rng::new(3));
    let space = agora::cluster::ConfigSpace::standard();
    results.push(bench::measure("host predictor fit+grid (16x96)", 5, 50, || {
        let pred = LearnedPredictor::fit(&logs);
        let g = pred.predict(&space);
        std::hint::black_box(g.get(0, 0));
    }));

    let artifacts = ArtifactManifest::default_dir();
    if artifacts.join("manifest.json").exists() {
        let engine = Engine::new(&artifacts).expect("artifacts load");
        let pjrt = PjrtPredictor::new(&engine);
        let fits: Vec<_> = logs.iter().map(LearnedPredictor::fit_task).collect();
        // warm the executable cache before timing
        let _ = pjrt.predict_fitted(&fits, &space).unwrap();
        results.push(bench::measure("PJRT predictor grid (cached exe)", 3, 30, || {
            let g = pjrt.predict_fitted(&fits, &space).unwrap();
            std::hint::black_box(g.get(0, 0));
        }));
        results.push(bench::measure("PJRT fit_predict (fused artifact)", 3, 30, || {
            let (g, _) = pjrt.fit_predict(&logs, &space).unwrap();
            std::hint::black_box(g.get(0, 0));
        }));
    } else {
        println!("(artifacts/ missing: run `make artifacts` for the PJRT rows)");
    }

    println!(
        "\nportfolio speedup (4 chains vs 1 chain, equal {budget}-proposal budget): {}",
        bench::speedup(single_m.mean, quad_m.mean)
    );
    println!(
        "solution quality at equal budget: single-chain energy {single_energy:.4}, \
         portfolio energy {quad_energy:.4} ({})",
        if quad_energy <= single_energy + 1e-9 {
            "portfolio at least as good"
        } else if quad_energy <= single_energy.min(0.0) * 0.95 {
            "within 5% of single-chain improvement"
        } else {
            "single chain ahead at this seed"
        }
    );

    println!();
    bench::table(
        &["hot path", "mean", "min", "max", "reps"],
        &results
            .iter()
            .map(|m| {
                vec![
                    m.name.clone(),
                    format!("{:.3} ms", m.mean_ms()),
                    format!("{:.3} ms", m.min.as_secs_f64() * 1e3),
                    format!("{:.3} ms", m.max.as_secs_f64() * 1e3),
                    m.reps.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    );
}
