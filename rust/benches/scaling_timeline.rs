//! Scaling sweep of the shared capacity-timeline kernel
//! (`solver::timeline`): 50 → 2000-task large-scale DAGs
//! (`dag::generator::large_scale_dag`), comparing the production
//! sweep-line kernel against the historical rectangle-list kernel
//! (retained verbatim in `solver::timeline::reference`) on the same
//! problems, and recording the end-to-end optimizer trajectory.
//!
//! Outputs:
//!   * a table per size: serial-SGS and multistart-optimizer wall-clock
//!     for both kernels, the speedup, and a full co-optimization round
//!     (incremental SA) on the production kernel;
//!   * `BENCH_timeline.json` at the repo root with the same numbers, so
//!     the perf trajectory is diffable across PRs.
//!
//! Every measured pair is also cross-checked for **bit-identical**
//! schedules — the speedup claim is only meaningful because the two
//! kernels agree exactly.
//!
//! `cargo bench --bench scaling_timeline -- --smoke` runs the smallest
//! size only (CI keeps the JSON generation path alive without paying for
//! the full sweep). The reference kernel is skipped above
//! `REF_MAX_TASKS` tasks — its O(n³) serial pass is the very cost this
//! kernel removed.

use std::path::Path;

use agora::bench;
use agora::cluster::{ConfigSpace, CostModel};
use agora::dag::generator::large_scale_dag;
use agora::predictor::OraclePredictor;
use agora::solver::sgs::{self, Rule};
use agora::solver::timeline::reference;
use agora::solver::{Agora, AgoraOptions, AnnealParams, Goal, Mode, Problem, Schedule};
use agora::trace::TraceParams;
use agora::util::{Json, Rng};
use agora::Predictor;

const SEED: u64 = 2022;
/// Largest size the historical kernel is timed at; beyond this its
/// O(n³) serial pass dominates the whole bench run.
const REF_MAX_TASKS: usize = 1000;
/// Noisy multistart restarts per optimizer measurement (on top of the
/// five static rules).
const RESTARTS: usize = 2;

/// A large-scale problem over the Alibaba-like batch slice of the
/// cluster, with per-task configs cycled through the feasible set so the
/// packing is genuinely contended.
fn problem_of(n: usize) -> (Problem, Vec<usize>) {
    let dag = large_scale_dag(&mut Rng::new(SEED ^ n as u64), &format!("scale{n}"), n);
    let space = ConfigSpace::standard();
    let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let p = Problem::new(
        &[dag],
        &[0.0],
        TraceParams::default().batch_capacity(),
        space,
        grid,
        CostModel::OnDemand,
    );
    let assignment: Vec<usize> = (0..p.len())
        .map(|t| p.feasible[t % p.feasible.len()])
        .collect();
    (p, assignment)
}

/// The historical multistart optimizer, verbatim, over the reference
/// kernel — same rules, same noisy-restart RNG stream as
/// `sgs::multistart_sgs`, so the two produce bit-identical schedules.
fn multistart_ref(
    p: &Problem,
    assignment: &[usize],
    extra_random: usize,
    rng: &mut Rng,
) -> Schedule {
    let mut best: Option<(f64, Schedule)> = None;
    let mut consider = |s: Schedule, p: &Problem| {
        let m = s.makespan(p);
        if best.as_ref().map_or(true, |(bm, _)| m < *bm) {
            best = Some((m, s));
        }
    };
    for &rule in sgs::ALL_RULES {
        let prio = sgs::priorities(p, assignment, rule);
        consider(reference::serial_sgs_ref(p, assignment, &prio), p);
    }
    let base = sgs::priorities(p, assignment, Rule::CriticalPath);
    let scale = base.iter().cloned().fold(0.0f64, f64::max).max(1.0);
    for _ in 0..extra_random {
        let noisy: Vec<f64> = base
            .iter()
            .map(|&b| b + rng.uniform(0.0, 0.3 * scale))
            .collect();
        consider(reference::serial_sgs_ref(p, assignment, &noisy), p);
    }
    best.expect("at least one rule ran").1
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench::header(
        "Timeline scaling",
        "sweep-line kernel vs historical rectangle list, 50-2000-task DAGs",
    );
    let sizes: &[usize] = if smoke {
        &[50]
    } else {
        &[50, 200, 500, 1000, 2000]
    };
    println!(
        "mode: {} | reference kernel timed up to {REF_MAX_TASKS} tasks",
        if smoke { "smoke (--smoke)" } else { "full sweep" }
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut points: Vec<Json> = Vec::new();
    let mut speedup_at_1000: Option<f64> = None;

    for &n in sizes {
        let (p, assignment) = problem_of(n);
        let prio = sgs::priorities(&p, &assignment, Rule::CriticalPath);

        // Equivalence pin before any timing: bit-identical serial SGS.
        let new_sched =
            sgs::serial_sgs(&p, &assignment, &prio).expect("feasible assignment");
        if n <= REF_MAX_TASKS {
            let ref_sched = reference::serial_sgs_ref(&p, &assignment, &prio);
            for t in 0..p.len() {
                assert_eq!(
                    new_sched.start[t].to_bits(),
                    ref_sched.start[t].to_bits(),
                    "kernel divergence at {n} tasks, task {t}"
                );
            }
            // Multistart draws the same noisy-restart stream on both
            // sides: the winners must match bit-for-bit too.
            let new_multi =
                sgs::multistart_sgs(&p, &assignment, RESTARTS, &mut Rng::new(SEED))
                    .expect("feasible assignment");
            let ref_multi = multistart_ref(&p, &assignment, RESTARTS, &mut Rng::new(SEED));
            assert_eq!(
                new_multi.makespan(&p).to_bits(),
                ref_multi.makespan(&p).to_bits(),
                "multistart divergence at {n} tasks"
            );
        }
        new_sched.validate(&p).expect("kernel produced invalid schedule");

        let (warm, reps) = match n {
            0..=200 => (2, 20),
            201..=500 => (1, 10),
            501..=1000 => (1, 5),
            _ => (1, 3),
        };
        let sgs_new = bench::measure(&format!("serial SGS new ({n})"), warm, reps, || {
            let s = sgs::serial_sgs(&p, &assignment, &prio).expect("feasible");
            std::hint::black_box(s.start[0]);
        });
        let multi_new = bench::measure(&format!("multistart new ({n})"), 0, reps.min(5), || {
            let mut rng = Rng::new(SEED);
            let s = sgs::multistart_sgs(&p, &assignment, RESTARTS, &mut rng)
                .expect("feasible");
            std::hint::black_box(s.start[0]);
        });

        let (sgs_ref, multi_ref) = if n <= REF_MAX_TASKS {
            let ref_reps = if n <= 200 { 3 } else { 1 };
            let a = bench::measure(&format!("serial SGS ref ({n})"), 0, ref_reps, || {
                let s = reference::serial_sgs_ref(&p, &assignment, &prio);
                std::hint::black_box(s.start[0]);
            });
            let b = bench::measure(&format!("multistart ref ({n})"), 0, 1, || {
                let mut rng = Rng::new(SEED);
                let s = multistart_ref(&p, &assignment, RESTARTS, &mut rng);
                std::hint::black_box(s.start[0]);
            });
            (Some(a), Some(b))
        } else {
            (None, None)
        };

        // End-to-end co-optimization round on the production kernel
        // (incremental SA — the checkpoint/rollback hot path).
        let sa = bench::measure(&format!("co-optimize SA ({n})"), 0, 1, || {
            let plan = Agora::new(AgoraOptions {
                goal: Goal::Balanced,
                mode: Mode::CoOptimize,
                params: AnnealParams {
                    max_iters: 200,
                    incremental: true,
                    ..AnnealParams::fast()
                },
                seed: SEED,
                ..Default::default()
            })
            .optimize(&p);
            std::hint::black_box(plan.makespan);
        });

        let optimizer_speedup = multi_ref
            .as_ref()
            .map(|r| r.mean.as_secs_f64() / multi_new.mean.as_secs_f64().max(1e-12));
        if n == 1000 {
            speedup_at_1000 = optimizer_speedup;
        }

        let fmt_opt = |m: &Option<bench::Measurement>| {
            m.as_ref()
                .map(|m| format!("{:.2}", m.mean_ms()))
                .unwrap_or_else(|| "-".into())
        };
        rows.push(vec![
            n.to_string(),
            format!("{:.2}", sgs_new.mean_ms()),
            fmt_opt(&sgs_ref),
            format!("{:.2}", multi_new.mean_ms()),
            fmt_opt(&multi_ref),
            optimizer_speedup
                .map(|s| format!("{s:.1}x"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.0}", sa.mean_ms()),
        ]);

        points.push(Json::obj(vec![
            ("tasks", Json::num(n as f64)),
            ("serial_sgs_ms", Json::num(sgs_new.mean_ms())),
            (
                "serial_sgs_ref_ms",
                sgs_ref
                    .as_ref()
                    .map(|m| Json::num(m.mean_ms()))
                    .unwrap_or(Json::Null),
            ),
            ("multistart_ms", Json::num(multi_new.mean_ms())),
            (
                "multistart_ref_ms",
                multi_ref
                    .as_ref()
                    .map(|m| Json::num(m.mean_ms()))
                    .unwrap_or(Json::Null),
            ),
            (
                "optimizer_speedup",
                optimizer_speedup.map(Json::num).unwrap_or(Json::Null),
            ),
            ("cooptimize_sa_ms", Json::num(sa.mean_ms())),
        ]));
    }

    bench::table(
        &[
            "tasks",
            "sgs new (ms)",
            "sgs ref (ms)",
            "multistart new (ms)",
            "multistart ref (ms)",
            "optimizer speedup",
            "SA round (ms)",
        ],
        &rows,
    );

    if let Some(s) = speedup_at_1000 {
        println!(
            "\noptimizer speedup at the 1000-task point: {s:.1}x (acceptance target: >= 5x)"
        );
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("scaling_timeline")),
        ("seed", Json::num(SEED as f64)),
        ("smoke", Json::Bool(smoke)),
        ("restarts", Json::num(RESTARTS as f64)),
        ("ref_max_tasks", Json::num(REF_MAX_TASKS as f64)),
        (
            "speedup_at_1000",
            speedup_at_1000.map(Json::num).unwrap_or(Json::Null),
        ),
        ("points", Json::Arr(points)),
    ]);
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_timeline.json");
    match std::fs::write(&out, doc.to_pretty() + "\n") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
