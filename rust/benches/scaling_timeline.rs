//! Scaling sweep of the shared capacity-timeline kernel
//! (`solver::timeline`): 50 → 100_000-task large-scale DAGs
//! (`dag::generator::large_scale_dag`), comparing three generations of
//! the kernel on the same problems:
//!
//!   * the production block-indexed profile (`Timeline`);
//!   * the PR 4 flat sorted-`Vec` sweep-line, retained verbatim as an
//!     executable reference (`timeline::flat`) — O(log n + k) queries
//!     but O(n) memmove per placement;
//!   * the historical rectangle list (`timeline::reference`) — O(n²)
//!     queries, timed only up to `REF_MAX_TASKS`.
//!
//! Outputs:
//!   * a table per size: serial-SGS and multistart-optimizer wall-clock
//!     for the kernels, the speedups, and a full co-optimization round
//!     (incremental SA) on the production kernel;
//!   * `BENCH_timeline.json` at the repo root with the same numbers plus
//!     the fitted scaling exponent, so the perf trajectory is diffable
//!     across PRs.
//!
//! Every measured size is cross-checked for **bit-identical** schedules
//! against the flat kernel (and additionally against the rectangle list
//! up to `REF_MAX_TASKS`) — the speedup claims are only meaningful
//! because the kernels agree exactly. Skipped measurements are logged
//! explicitly; a silent cap would read as full coverage.
//!
//! CI gates (asserted here, in `--smoke` mode and in the full sweep):
//!   * the fitted scaling exponent of the indexed serial-SGS pass over
//!     the sizes >= 2000 stays below `MAX_SGS_EXPONENT` — an accidental
//!     O(n²) regression in `place` fails the bench, not just slows it;
//!   * at every timed size >= 10_000 the indexed kernel beats the flat
//!     kernel on serial-SGS wall clock.
//!
//! `cargo bench --bench scaling_timeline -- --smoke` runs the reduced
//! size list [50, 2000, 10_000] (the CI mode).

use std::path::Path;

use agora::bench;
use agora::cluster::{ConfigSpace, CostModel};
use agora::dag::generator::large_scale_dag;
use agora::predictor::OraclePredictor;
use agora::solver::sgs::{self, Rule};
use agora::solver::timeline::{flat, reference};
use agora::solver::{Agora, AgoraOptions, AnnealParams, Goal, Mode, Problem, Schedule};
use agora::trace::TraceParams;
use agora::util::{Json, Rng};
use agora::Predictor;

const SEED: u64 = 2022;
/// Largest size the historical rectangle-list kernel is timed at; beyond
/// this its O(n³) serial pass dominates the whole bench run. The
/// bit-identical cross-check stays alive above it via the flat kernel.
const REF_MAX_TASKS: usize = 1000;
/// Largest size the flat kernel's multistart (7 full passes) is timed
/// at; its O(n) memmove per placement makes the 30k+ points minutes-long
/// for no extra information — the serial pass is still timed (and
/// equivalence-checked) at every size.
const MULTI_FLAT_MAX_TASKS: usize = 10_000;
/// Largest size the end-to-end SA round is measured at in the full
/// sweep (the SA trajectory is an optimizer benchmark, not a kernel
/// one; `fig10_scaling` owns the optimizer story).
const SA_MAX_TASKS: usize = 30_000;
/// Fitted-exponent ceiling for the indexed serial-SGS pass over the
/// sizes >= `FIT_MIN_TASKS`. Healthy block-indexed passes fit ~1.1-1.4
/// (n log n with growing segment counts); an O(n²) `place` regression
/// fits ~2.0.
const MAX_SGS_EXPONENT: f64 = 1.8;
/// Smallest size included in the exponent fit — below this, constant
/// overheads (problem setup, priority computation) pollute the slope.
const FIT_MIN_TASKS: usize = 2000;
/// Noisy multistart restarts per optimizer measurement (on top of the
/// five static rules).
const RESTARTS: usize = 2;

/// A large-scale problem over the Alibaba-like batch slice of the
/// cluster, with per-task configs cycled through the feasible set so the
/// packing is genuinely contended.
fn problem_of(n: usize) -> (Problem, Vec<usize>) {
    let dag = large_scale_dag(&mut Rng::new(SEED ^ n as u64), &format!("scale{n}"), n);
    let space = ConfigSpace::standard();
    let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
    let grid = OraclePredictor { profiles }.predict(&space);
    let p = Problem::new(
        &[dag],
        &[0.0],
        TraceParams::default().batch_capacity(),
        space,
        grid,
        CostModel::OnDemand,
    );
    let assignment: Vec<usize> = (0..p.len())
        .map(|t| p.feasible[t % p.feasible.len()])
        .collect();
    (p, assignment)
}

/// The multistart optimizer over a pluggable serial-SGS pass — same
/// rules, same noisy-restart RNG stream as `sgs::multistart_sgs`, so
/// every kernel produces bit-identical winners.
fn multistart_with(
    p: &Problem,
    assignment: &[usize],
    extra_random: usize,
    rng: &mut Rng,
    sgs_pass: impl Fn(&Problem, &[usize], &[f64]) -> Schedule,
) -> Schedule {
    let mut best: Option<(f64, Schedule)> = None;
    let mut consider = |s: Schedule, p: &Problem| {
        let m = s.makespan(p);
        if best.as_ref().map_or(true, |(bm, _)| m < *bm) {
            best = Some((m, s));
        }
    };
    for &rule in sgs::ALL_RULES {
        let prio = sgs::priorities(p, assignment, rule);
        consider(sgs_pass(p, assignment, &prio), p);
    }
    let base = sgs::priorities(p, assignment, Rule::CriticalPath);
    let scale = base.iter().cloned().fold(0.0f64, f64::max).max(1.0);
    for _ in 0..extra_random {
        let noisy: Vec<f64> = base
            .iter()
            .map(|&b| b + rng.uniform(0.0, 0.3 * scale))
            .collect();
        consider(sgs_pass(p, assignment, &noisy), p);
    }
    best.expect("at least one rule ran").1
}

fn assert_bit_identical(a: &Schedule, b: &Schedule, n: usize, what: &str) {
    assert_eq!(a.start.len(), b.start.len());
    for t in 0..a.start.len() {
        assert_eq!(
            a.start[t].to_bits(),
            b.start[t].to_bits(),
            "{what} divergence at {n} tasks, task {t}: {} vs {}",
            a.start[t],
            b.start[t]
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench::header(
        "Timeline scaling",
        "block-indexed kernel vs flat sweep-line vs rectangle list, 50-100k-task DAGs",
    );
    let sizes: &[usize] = if smoke {
        &[50, 2000, 10_000]
    } else {
        &[50, 200, 1000, 2000, 10_000, 30_000, 100_000]
    };
    println!(
        "mode: {} | rectangle-list reference timed up to {REF_MAX_TASKS} tasks",
        if smoke { "smoke (--smoke)" } else { "full sweep" }
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut points: Vec<Json> = Vec::new();
    let mut speedup_at_1000: Option<f64> = None;
    let mut fit_points: Vec<(f64, f64)> = Vec::new();

    for &n in sizes {
        let (p, assignment) = problem_of(n);
        let prio = sgs::priorities(&p, &assignment, Rule::CriticalPath);

        // Equivalence pins before any timing. The flat kernel is the
        // always-on executable reference: bit-identical serial SGS at
        // EVERY measured size; the rectangle list corroborates up to
        // REF_MAX_TASKS.
        let new_sched =
            sgs::serial_sgs(&p, &assignment, &prio).expect("feasible assignment");
        let flat_sched = flat::serial_sgs_flat(&p, &assignment, &prio);
        assert_bit_identical(&new_sched, &flat_sched, n, "indexed/flat serial-SGS");
        if n <= REF_MAX_TASKS {
            let ref_sched = reference::serial_sgs_ref(&p, &assignment, &prio);
            assert_bit_identical(&new_sched, &ref_sched, n, "indexed/rect serial-SGS");
        } else {
            println!(
                "skip: rectangle-list reference not run at {n} tasks \
                 (> REF_MAX_TASKS = {REF_MAX_TASKS}); equivalence carried by the \
                 flat-Vec kernel at this size"
            );
        }
        new_sched.validate(&p).expect("kernel produced invalid schedule");

        // Multistart winners must match bit-for-bit too (same RNG
        // stream on every kernel).
        if n <= MULTI_FLAT_MAX_TASKS {
            let new_multi =
                sgs::multistart_sgs(&p, &assignment, RESTARTS, &mut Rng::new(SEED))
                    .expect("feasible assignment");
            let flat_multi = multistart_with(
                &p,
                &assignment,
                RESTARTS,
                &mut Rng::new(SEED),
                flat::serial_sgs_flat,
            );
            assert_bit_identical(&new_multi, &flat_multi, n, "indexed/flat multistart");
            if n <= REF_MAX_TASKS {
                let ref_multi = multistart_with(
                    &p,
                    &assignment,
                    RESTARTS,
                    &mut Rng::new(SEED),
                    reference::serial_sgs_ref,
                );
                assert_eq!(
                    new_multi.makespan(&p).to_bits(),
                    ref_multi.makespan(&p).to_bits(),
                    "multistart divergence at {n} tasks"
                );
            }
        } else {
            println!(
                "skip: multistart equivalence/timing for the flat kernel not run at \
                 {n} tasks (> MULTI_FLAT_MAX_TASKS = {MULTI_FLAT_MAX_TASKS}); \
                 serial-SGS equivalence above covers the kernel contract"
            );
        }

        let (warm, reps) = match n {
            0..=200 => (2, 20),
            201..=1000 => (1, 10),
            1001..=2000 => (1, 5),
            2001..=10_000 => (1, 3),
            _ => (0, 2),
        };
        let sgs_new = bench::measure(&format!("serial SGS indexed ({n})"), warm, reps, || {
            let s = sgs::serial_sgs(&p, &assignment, &prio).expect("feasible");
            std::hint::black_box(s.start[0]);
        });
        let flat_reps = if n <= 2000 { 3 } else { 1 };
        let sgs_flat = bench::measure(&format!("serial SGS flat ({n})"), 0, flat_reps, || {
            let s = flat::serial_sgs_flat(&p, &assignment, &prio);
            std::hint::black_box(s.start[0]);
        });
        let multi_new = bench::measure(&format!("multistart indexed ({n})"), 0, reps.min(5), || {
            let mut rng = Rng::new(SEED);
            let s = sgs::multistart_sgs(&p, &assignment, RESTARTS, &mut rng)
                .expect("feasible");
            std::hint::black_box(s.start[0]);
        });
        let multi_flat = if n <= MULTI_FLAT_MAX_TASKS {
            Some(bench::measure(&format!("multistart flat ({n})"), 0, 1, || {
                let mut rng = Rng::new(SEED);
                let s = multistart_with(&p, &assignment, RESTARTS, &mut rng, flat::serial_sgs_flat);
                std::hint::black_box(s.start[0]);
            }))
        } else {
            None
        };

        let (sgs_ref, multi_ref) = if n <= REF_MAX_TASKS {
            let ref_reps = if n <= 200 { 3 } else { 1 };
            let a = bench::measure(&format!("serial SGS rect ({n})"), 0, ref_reps, || {
                let s = reference::serial_sgs_ref(&p, &assignment, &prio);
                std::hint::black_box(s.start[0]);
            });
            let b = bench::measure(&format!("multistart rect ({n})"), 0, 1, || {
                let mut rng = Rng::new(SEED);
                let s = multistart_with(
                    &p,
                    &assignment,
                    RESTARTS,
                    &mut rng,
                    reference::serial_sgs_ref,
                );
                std::hint::black_box(s.start[0]);
            });
            (Some(a), Some(b))
        } else {
            (None, None)
        };

        // End-to-end co-optimization round on the production kernel
        // (incremental SA — the checkpoint/rollback hot path). In smoke
        // mode only the sizes the CI budget affords.
        let sa_cap = if smoke { 2000 } else { SA_MAX_TASKS };
        let sa = if n <= sa_cap {
            Some(bench::measure(&format!("co-optimize SA ({n})"), 0, 1, || {
                let plan = Agora::new(AgoraOptions {
                    goal: Goal::Balanced,
                    mode: Mode::CoOptimize,
                    params: AnnealParams {
                        max_iters: 200,
                        incremental: true,
                        ..AnnealParams::fast()
                    },
                    seed: SEED,
                    ..Default::default()
                })
                .optimize(&p);
                std::hint::black_box(plan.makespan);
            }))
        } else {
            println!(
                "skip: co-optimize SA round not run at {n} tasks (> {sa_cap} in this mode)"
            );
            None
        };

        let optimizer_speedup = multi_ref
            .as_ref()
            .map(|r| r.mean.as_secs_f64() / multi_new.mean.as_secs_f64().max(1e-12));
        if n == 1000 {
            speedup_at_1000 = optimizer_speedup;
        }
        let sgs_speedup_vs_flat = sgs_flat.min.as_secs_f64() / sgs_new.min.as_secs_f64().max(1e-12);
        if n >= FIT_MIN_TASKS {
            fit_points.push((n as f64, sgs_new.min_ms()));
        }

        // CI gate: at production scale the indexed kernel must beat the
        // flat kernel on the serial-SGS wall clock.
        if n >= 10_000 {
            assert!(
                sgs_new.min < sgs_flat.min,
                "indexed kernel ({:.2} ms) not faster than the flat kernel \
                 ({:.2} ms) at {n} tasks",
                sgs_new.min_ms(),
                sgs_flat.min_ms(),
            );
        }

        let fmt_opt = |m: &Option<bench::Measurement>| {
            m.as_ref()
                .map(|m| format!("{:.2}", m.mean_ms()))
                .unwrap_or_else(|| "-".into())
        };
        rows.push(vec![
            n.to_string(),
            format!("{:.2}", sgs_new.mean_ms()),
            format!("{:.2}", sgs_flat.mean_ms()),
            fmt_opt(&sgs_ref),
            format!("{sgs_speedup_vs_flat:.1}x"),
            format!("{:.2}", multi_new.mean_ms()),
            fmt_opt(&multi_flat),
            fmt_opt(&multi_ref),
            optimizer_speedup
                .map(|s| format!("{s:.1}x"))
                .unwrap_or_else(|| "-".into()),
            sa.as_ref()
                .map(|m| format!("{:.0}", m.mean_ms()))
                .unwrap_or_else(|| "-".into()),
        ]);

        points.push(Json::obj(vec![
            ("tasks", Json::num(n as f64)),
            ("serial_sgs_ms", Json::num(sgs_new.mean_ms())),
            ("serial_sgs_min_ms", Json::num(sgs_new.min_ms())),
            ("serial_sgs_flat_ms", Json::num(sgs_flat.mean_ms())),
            ("serial_sgs_flat_min_ms", Json::num(sgs_flat.min_ms())),
            (
                "serial_sgs_ref_ms",
                sgs_ref
                    .as_ref()
                    .map(|m| Json::num(m.mean_ms()))
                    .unwrap_or(Json::Null),
            ),
            ("sgs_speedup_vs_flat", Json::num(sgs_speedup_vs_flat)),
            ("multistart_ms", Json::num(multi_new.mean_ms())),
            (
                "multistart_flat_ms",
                multi_flat
                    .as_ref()
                    .map(|m| Json::num(m.mean_ms()))
                    .unwrap_or(Json::Null),
            ),
            (
                "multistart_ref_ms",
                multi_ref
                    .as_ref()
                    .map(|m| Json::num(m.mean_ms()))
                    .unwrap_or(Json::Null),
            ),
            (
                "optimizer_speedup",
                optimizer_speedup.map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "cooptimize_sa_ms",
                sa.as_ref().map(|m| Json::num(m.mean_ms())).unwrap_or(Json::Null),
            ),
        ]));
    }

    bench::table(
        &[
            "tasks",
            "sgs idx (ms)",
            "sgs flat (ms)",
            "sgs rect (ms)",
            "idx/flat",
            "multi idx (ms)",
            "multi flat (ms)",
            "multi rect (ms)",
            "speedup vs rect",
            "SA round (ms)",
        ],
        &rows,
    );

    if let Some(s) = speedup_at_1000 {
        println!(
            "\noptimizer speedup at the 1000-task point: {s:.1}x (acceptance target: >= 5x)"
        );
    }

    // CI gate: the fitted scaling exponent of the indexed serial-SGS
    // pass. An O(n²)-regressed `place` fits ~2.0; healthy block-indexed
    // passes fit ~1.1-1.4.
    let exponent = bench::fit_log_log_slope(&fit_points);
    match exponent {
        Some(e) => {
            println!(
                "fitted serial-SGS scaling exponent over sizes >= {FIT_MIN_TASKS}: \
                 n^{e:.2} (ceiling n^{MAX_SGS_EXPONENT})"
            );
            assert!(
                e <= MAX_SGS_EXPONENT,
                "serial-SGS pass scales as n^{e:.2} > n^{MAX_SGS_EXPONENT}: \
                 the placement path has regressed toward O(n²)"
            );
        }
        None => println!(
            "skip: scaling-exponent fit needs >= 2 sizes at or above {FIT_MIN_TASKS} tasks"
        ),
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("scaling_timeline")),
        ("provenance", Json::str("measured")),
        ("seed", Json::num(SEED as f64)),
        ("smoke", Json::Bool(smoke)),
        ("restarts", Json::num(RESTARTS as f64)),
        ("ref_max_tasks", Json::num(REF_MAX_TASKS as f64)),
        (
            "speedup_at_1000",
            speedup_at_1000.map(Json::num).unwrap_or(Json::Null),
        ),
        (
            "sgs_scaling_exponent",
            exponent.map(Json::num).unwrap_or(Json::Null),
        ),
        ("points", Json::Arr(points)),
    ]);
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_timeline.json");
    match std::fs::write(&out, doc.to_pretty() + "\n") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
