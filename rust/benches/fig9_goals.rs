//! Fig. 9 — cost/performance frontier as the optimization weight slides
//! from pure cost (w = 0) through balanced (w = 0.5) to pure runtime
//! (w = 1), for DAG1 (circles in the paper) and DAG2 (triangles).
//!
//! Paper's observations to reproduce: cost-goal points sit top-left
//! (cheap, slow), runtime-goal points bottom-right (fast, pricey),
//! balanced in between; DAG2's curve is stiffer (more runtime headroom).

#[path = "common/mod.rs"]
mod common;

use agora::bench;
use agora::dag::workloads::{dag1, dag2};
use agora::solver::Goal;
use agora::util::{fmt_cost, fmt_duration, Rng};

fn main() {
    bench::header("Figure 9", "goal sweep: runtime/cost frontier per DAG");

    for (dag_name, dag_fn) in [("DAG1", dag1 as fn() -> agora::Dag), ("DAG2", dag2)] {
        let mut rng = Rng::new(common::SEED);
        let (p, dags) = common::learned_problem(vec![dag_fn()], &mut rng);
        // anchor for the cost goal's makespan budget
        let base = {
            use agora::baselines::{AirflowScheduler, Scheduler};
            let s = AirflowScheduler::default().schedule(&p).expect("airflow");
            common::realize(&p, &dags, &s).0
        };

        println!("\n-- {dag_name} --");
        let mut rows = Vec::new();
        let mut frontier = Vec::new();
        for (label, goal) in [
            ("cost (w=0)", Goal::Cost),
            ("w=0.25", Goal::Weighted(0.25)),
            ("balanced (w=0.5)", Goal::Balanced),
            ("w=0.75", Goal::Weighted(0.75)),
            ("runtime (w=1)", Goal::Runtime),
        ] {
            let plan = common::agora_plan(&p, goal, base);
            let (m, c) = common::realize(&p, &dags, &plan.schedule);
            frontier.push((label, m, c));
            rows.push(vec![label.to_string(), fmt_duration(m), fmt_cost(c)]);
        }
        bench::table(&["goal", "runtime", "cost"], &rows);

        // Frontier direction checks.
        let cost_pt = frontier[0];
        let runtime_pt = frontier[frontier.len() - 1];
        println!(
            "frontier: cost-goal ({}, {}) vs runtime-goal ({}, {}) -> {}",
            fmt_duration(cost_pt.1),
            fmt_cost(cost_pt.2),
            fmt_duration(runtime_pt.1),
            fmt_cost(runtime_pt.2),
            if cost_pt.2 <= runtime_pt.2 && runtime_pt.1 <= cost_pt.1 {
                "correct orientation (cheap-slow vs fast-pricey)"
            } else {
                "orientation degraded by prediction noise at this seed"
            }
        );
    }
}
